"""qwen3-14b [dense]: 40L d=5120 40H kv=8 ff=17408, qk-norm.
[hf:Qwen/Qwen3-8B(family); hf]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-14b", family="dense",
    num_layers=40, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=17408, vocab_size=151936, head_dim=128,
    qk_norm=True, rope_theta=1000000.0,
)
