"""``python -m repro.obs`` — read exported Chrome-trace JSON in a
terminal.

Subcommands:

  * ``render <trace.json>`` — text timeline of the recorded spans
    (indented by nesting, with a proportional position bar) plus a
    phase breakdown table (per span name: count, total ms, share of
    wall) and a superstep-counter summary. This is the quick answer to
    "where did that serve_under_churn run spend its time" without
    leaving the shell; load the same file into https://ui.perfetto.dev
    for the interactive view.
  * ``validate <trace.json>`` — run the Chrome-trace schema check
    (:func:`repro.obs.export.validate`); exit 1 on any error. CI runs
    this over the trace-smoke artifact.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs import export


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def _spans(payload: dict) -> list[dict]:
    return [e for e in payload.get("traceEvents", [])
            if e.get("ph") == "X"]


def cmd_validate(args) -> int:
    payload = _load(args.trace)
    errors = export.validate(payload)
    for e in errors:
        print(f"INVALID: {e}")
    n = len(payload.get("traceEvents", []))
    print(f"{args.trace}: {n} events, "
          f"{'INVALID' if errors else 'valid chrome-trace JSON'}")
    return 1 if errors else 0


def _phase_table(spans: list[dict], wall_us: float) -> list[str]:
    agg: dict[tuple, list] = {}
    for e in spans:
        key = (e.get("cat", "default"), e["name"])
        a = agg.setdefault(key, [0, 0.0])
        a[0] += 1
        a[1] += float(e.get("dur", 0.0))
    lines = [f"{'phase':<28}{'count':>7}{'total_ms':>12}{'wall%':>8}"]
    for (cat, name), (cnt, tot) in sorted(agg.items(),
                                          key=lambda kv: -kv[1][1]):
        share = 100.0 * tot / wall_us if wall_us else 0.0
        lines.append(f"{cat + '/' + name:<28}{cnt:>7}"
                     f"{tot / 1e3:>12.2f}{share:>7.1f}%")
    return lines


def _timeline(spans: list[dict], width: int, limit: int) -> list[str]:
    t0 = min(e["ts"] for e in spans)
    t1 = max(e["ts"] + e.get("dur", 0.0) for e in spans)
    wall = max(t1 - t0, 1e-9)
    ordered = sorted(spans, key=lambda e: (e["ts"], -e.get("dur", 0.0)))
    dropped = 0
    if len(ordered) > limit:
        # keep the longest spans (they carry the structure), in ts order
        keep = set(id(e) for e in sorted(
            ordered, key=lambda e: -e.get("dur", 0.0))[:limit])
        dropped = len(ordered) - limit
        ordered = [e for e in ordered if id(e) in keep]
    out = []
    stack: list[float] = []  # open-span end times -> nesting depth
    for e in ordered:
        end = e["ts"] + e.get("dur", 0.0)
        while stack and e["ts"] >= stack[-1] - 1e-9:
            stack.pop()
        depth = len(stack)
        stack.append(end)
        at = int((e["ts"] - t0) / wall * width)
        ln = max(1, int(e.get("dur", 0.0) / wall * width))
        bar = " " * min(at, width - 1) + "#" * min(ln, width - at)
        label = ("  " * depth + e["name"])[:24]
        out.append(f"{label:<24}{e.get('dur', 0.0) / 1e3:>10.2f}ms "
                   f"|{bar:<{width}}|")
    if dropped:
        out.append(f"... {dropped} shorter span(s) omitted "
                   f"(--limit {limit})")
    return out


def _counter_summary(payload: dict) -> list[str]:
    counters = [e for e in payload.get("traceEvents", [])
                if e.get("ph") == "C"]
    if not counters:
        return []
    totals: dict[str, float] = {}
    for e in counters:
        for k, v in e.get("args", {}).items():
            totals[k] = totals.get(k, 0) + v
    lines = [f"superstep counters ({len(counters)} samples):"]
    for k in sorted(totals):
        if k in ("superstep", "psd_sum", "psd_max", "width"):
            continue  # positional/instantaneous series — sums are noise
        lines.append(f"  {k:<22}{int(totals[k]):>16,}")
    return lines


def cmd_render(args) -> int:
    payload = _load(args.trace)
    spans = _spans(payload)
    print(f"== {args.trace} ==")
    dropped = payload.get("otherData", {}).get("dropped_events", 0)
    if dropped:
        print(f"(ring buffer dropped {dropped} oldest events)")
    if not spans:
        print("no span events recorded")
    else:
        wall_us = (max(e["ts"] + e.get("dur", 0.0) for e in spans)
                   - min(e["ts"] for e in spans))
        print(f"wall: {wall_us / 1e3:.2f}ms across {len(spans)} spans")
        print()
        print("-- timeline " + "-" * (args.width + 24))
        for line in _timeline(spans, args.width, args.limit):
            print(line)
        print()
        print("-- phase breakdown " + "-" * 36)
        for line in _phase_table(spans, wall_us):
            print(line)
    summary = _counter_summary(payload)
    if summary:
        print()
        for line in summary:
            print(line)
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs")
    sub = ap.add_subparsers(dest="cmd", required=True)
    r = sub.add_parser("render", help="text timeline + phase breakdown")
    r.add_argument("trace", help="exported Chrome-trace JSON file")
    r.add_argument("--width", type=int, default=60,
                   help="timeline bar width (columns)")
    r.add_argument("--limit", type=int, default=60,
                   help="max spans shown in the timeline")
    r.set_defaults(fn=cmd_render)
    v = sub.add_parser("validate", help="Chrome-trace schema check")
    v.add_argument("trace")
    v.set_defaults(fn=cmd_validate)
    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
