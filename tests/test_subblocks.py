"""Hierarchical partitions (sub-blocks): the second partition level that
breaks the P-pigeonhole. Acceptance properties — (1) the sub-block
engine, the flat (subblocks=1) engine, and the host reference loop all
land on the same fixpoint for every program class, with fused/host
DECISION parity at S > 1 (same loads, updates, iterations); (2) warm
streaming restarts (inserts AND deletes) stay correct under sub-block
re-heat and arm materially fewer sub-blocks than the block-granular
tracker's pigeonhole bound; (3) the streaming prewarm covers the
sub-block shapes — ingest after prewarm compiles nothing new."""
import dataclasses

import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import algorithms as A
from repro.core import graph as G
from repro.core import state as state_lib
from repro.core.engine import EngineConfig, StructureAwareEngine
from repro.serve import Query, QueryService
from repro.stream import (DeltaBatch, StreamConfig, StreamingEngine,
                          synthetic_stream)

CFG = EngineConfig(t2=1e-9, width=4, block_size=128)


def _close(a, b, **kw):
    return np.allclose(np.minimum(a, 1e18), np.minimum(b, 1e18), **kw)


# -- fixpoint + decision parity ----------------------------------------------
@given(n=st.integers(150, 700), seed=st.integers(0, 12),
       algo=st.sampled_from(["pagerank", "sssp", "cc"]),
       subblocks=st.sampled_from([2, 4]))
@settings(max_examples=8, deadline=None)
def test_subblock_fixpoint_property(n, seed, algo, subblocks):
    """Property (hierarchical tentpole): per-sub-block tracking changes
    which vertex ranges a block load sweeps, never the fixpoint — the
    S > 1 fused engine, the S > 1 host reference loop, and the flat
    S = 1 engine all converge to the same values; fused and host make
    the same schedule decisions (loads/updates/iterations) at S > 1
    exactly as the adaptive parity suite guarantees at S = 1."""
    g = G.powerlaw_graph(n, avg_deg=4, seed=seed, weighted=True)
    prog = {"pagerank": A.pagerank, "cc": A.cc,
            "sssp": lambda: A.sssp(0)}[algo]
    cfg = dataclasses.replace(CFG, subblocks=subblocks)
    rs_f = StructureAwareEngine(g, prog(), cfg).run(fused=True)
    rs_h = StructureAwareEngine(g, prog(), cfg).run(fused=False)
    r1 = StructureAwareEngine(
        g, prog(), dataclasses.replace(CFG, subblocks=1)).run(fused=True)
    assert rs_f.metrics.converged and rs_h.metrics.converged \
        and r1.metrics.converged
    # fused/host sub-block decision parity (mirrors the adaptive suite)
    assert _close(rs_f.values, rs_h.values, rtol=1e-5, atol=1e-6)
    assert abs(rs_f.metrics.iterations - rs_h.metrics.iterations) <= 1
    assert rs_f.metrics.updates == rs_h.metrics.updates
    assert rs_f.metrics.block_loads == rs_h.metrics.block_loads
    assert rs_f.metrics.bytes_loaded == rs_h.metrics.bytes_loaded
    # sub-block masking never changes the answer
    assert _close(rs_f.values, r1.values, rtol=1e-4, atol=1e-5)


def test_subblock_one_is_flat_state():
    """The S = 1 state helpers are the flat helpers with a trailing
    singleton axis, value for value — the invariant behind the bitwise
    S = 1 reproduction of the flat engine."""
    dirty = np.array([True, False, True, False])
    bump = np.array([0.0, 0.5, 0.0, 2.0], np.float32)
    flat = state_lib.warm_psd(4, dirty, bump)
    sub = state_lib.warm_psd_sub(4, 1, dirty[:, None], bump)
    assert sub.shape == (4, 1)
    assert np.array_equal(state_lib.fold_subblock_psd(sub), flat)
    calm_f = state_lib.warm_calm(4, dirty, 3)
    calm_s = state_lib.warm_calm_sub(4, 1, dirty[:, None], 3)
    assert np.array_equal(calm_s[:, 0], calm_f)
    # fold is identity on already-flat vectors
    assert state_lib.fold_subblock_psd(flat) is flat
    assert state_lib.converged(sub, 1.0) == state_lib.converged(flat, 1.0)


def test_subblock_metrics_degenerate_at_one():
    """At S = 1 every scheduled block is exactly one live sub-block:
    mean_subblock_dispatch is identically 1.0 and sub-block retirement
    equals block retirement."""
    g = G.powerlaw_graph(500, avg_deg=4, seed=3, weighted=True)
    r = StructureAwareEngine(g, A.pagerank(), CFG).run(fused=True)
    assert r.metrics.mean_subblock_dispatch == 1.0
    assert r.metrics.subblocks_retired == r.metrics.blocks_retired


# -- warm streaming restarts --------------------------------------------------
def test_warm_after_ingest_with_deletes_subblocks():
    """Sub-block re-heat over a mutating stream (inserts + deletes)
    matches the flat tracker's fixpoint AND a cold recompute, while
    arming no more sub-blocks than the pigeonhole bound (S x dirty
    blocks) and at least one per dirty block."""
    g = G.powerlaw_graph(900, avg_deg=5, seed=3, weighted=True)
    batches = synthetic_stream(g, 3, 40, seed=11, delete_frac=0.4,
                               weighted=True)
    se4 = StreamingEngine(g, A.pagerank(),
                          dataclasses.replace(CFG, subblocks=4))
    se1 = StreamingEngine(g, A.pagerank(), CFG)
    cold = StreamingEngine(g, A.pagerank(), CFG, StreamConfig(warm=False))
    for b in batches:
        r4 = se4.ingest(b)
        r1 = se1.ingest(b)
        cold.ingest(b)
        assert r4.subblocks == 4 and r1.subblocks == 1
        assert r4.dirty_blocks == r1.dirty_blocks  # block layer untouched
        assert r4.dirty_subblocks <= 4 * r4.dirty_blocks
        assert r4.dirty_subblocks >= r4.dirty_blocks
        assert r4.converged and r1.converged
    assert _close(se4.values, se1.values, rtol=1e-4, atol=1e-5)
    assert _close(se4.values, cold.values, rtol=1e-4, atol=1e-5)


def test_small_batch_breaks_pigeonhole():
    """The headline granularity win: a small edit batch's endpoints land
    in most BLOCKS (dirty_frac near 1 — the P-pigeonhole), but arm only
    a sliver of the SUB-BLOCK slots."""
    g = G.powerlaw_graph(4000, avg_deg=5, seed=2, weighted=True)
    se = StreamingEngine(g, A.pagerank(),
                         dataclasses.replace(CFG, subblocks=8))
    se.ingest(DeltaBatch.empty())
    batch = list(synthetic_stream(g, 1, 10, seed=5, weighted=True))[0]
    rep = se.ingest(batch)
    assert rep.dirty_blocks > 0
    assert rep.dirty_subblocks < rep.subblocks * rep.dirty_blocks
    # finer tracking: armed fraction strictly below the block tracker's
    assert rep.subblock_dirty_frac < rep.dirty_frac


def test_prewarm_covers_subblock_ingest_no_recompile():
    """Regression (prewarm satellite): after construction-time prewarm,
    an in-place ingest + warm reconvergence at S > 1 hits only compiled
    executables — no new jit entries, no new traces."""
    g = G.powerlaw_graph(500, avg_deg=5, seed=8, weighted=True)
    se = StreamingEngine(g, A.pagerank(),
                         dataclasses.replace(CFG, subblocks=4))
    se.ingest(DeltaBatch.empty())  # exercise the warm path once
    eng = se.engine

    def compiles():
        fns = list(eng._fns.values()) + [eng._post]
        return len(eng._fns), sum(f._cache_size() for f in fns)

    before = compiles()
    rep = se.ingest(DeltaBatch.of(ins=[(1, 2), (3, 4), (5, 6)]))
    assert not rep.plan_rebuild and se.engine is eng
    assert compiles() == before


# -- serving ------------------------------------------------------------------
def test_serve_subblock_parity():
    """Lane runs inherit the sub-block masks: a query batch at S > 1
    answers exactly like the flat service (values and per-lane
    convergence supersteps)."""
    g = G.powerlaw_graph(700, avg_deg=5, seed=5, weighted=True)

    def serve(subblocks):
        cfg = dataclasses.replace(CFG, subblocks=subblocks)
        svc = QueryService(StreamingEngine(g, A.sssp(), cfg), max_lanes=2,
                           prewarm=False)
        qids = [svc.submit(Query(kind="sssp", source=s)) for s in (3, 77)]
        res = {r.query_id: r for r in svc.run_pending()}
        return [res[q] for q in qids]

    r1, r4 = serve(1), serve(4)
    for a, b in zip(r1, r4):
        assert _close(a.values, b.values, rtol=1e-5, atol=1e-6)
        assert a.iterations == b.iterations
        assert a.converged and b.converged
