"""RA001 fixture: host-sync primitives inside traced code."""
import jax
import jax.numpy as jnp
import numpy as np
from jax import lax


def fused_body(values, psd):
    # .item() inside a jitted function: device->host sync per call
    hottest = psd.argmax().item()
    return values.at[hottest].add(1.0), psd


run = jax.jit(fused_body)


def make_sweep(width):
    def sweep(values, rows):
        # np.asarray on a traced operand materializes on host
        host_rows = np.asarray(rows)
        return values[host_rows[:width]]

    return sweep


def loop(values):
    def body(i, v):
        return v + float(v)  # float() on the traced carry

    return lax.fori_loop(0, 3, body, values)
