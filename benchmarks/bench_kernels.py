"""Kernel microbenchmarks: Pallas (interpret on CPU — correctness-path
timing only; TPU timing comes from the roofline terms) vs jnp oracles, plus
the XLA paths the models actually lower on this host."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.models.attention import chunked_attention, full_attention
from repro.models.ssm import ssd_chunked


def _time(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rng = np.random.default_rng(0)
    rows = []
    # spmv: jnp scatter-add oracle vs Pallas(one-hot MXU formulation,
    # interpret) — report both
    e, c = 8192, 512
    msg = jnp.asarray(rng.normal(size=e).astype(np.float32))
    dst = jnp.asarray(rng.integers(0, c, size=e).astype(np.int32))
    jr = jax.jit(lambda m, d: ref.edge_block_sum(m, d, c))
    rows.append((f"kernels/spmv_ref_E{e}_C{c}", _time(jr, msg, dst), "jnp"))
    rows.append((f"kernels/spmv_pallas_E{e}_C{c}",
                 _time(lambda m, d: ops.edge_block_sum(m, d, c), msg, dst),
                 "interpret=True (correctness path)"))
    # lane combine (the PPR hot spot fixed by the fused block sweep):
    # (TILE, L) edge messages into (C, L) destination slots — the serial
    # scatter vs the block_sweep kernel's one-hot matmul formulation.
    # Wall time here is XLA-on-CPU; the structural win is in the derived
    # columns: the scatter issues E*L dependent read-modify-writes on a
    # serial scatter unit, the matmul form is L MXU passes over
    # (128x128) systolic tiles with HBM traffic E reads + C*L writes.
    tile, cl = 512, 128
    for lanes in (1, 8):
        msg_l = jnp.asarray(rng.normal(size=(tile, lanes))
                            .astype(np.float32))
        dst_l = jnp.asarray(rng.integers(0, cl, size=tile)
                            .astype(np.int32))
        cols = jax.lax.broadcasted_iota(jnp.int32, (tile, cl), 1)

        def scatter(m, d):
            return jnp.zeros((cl, m.shape[1]), jnp.float32).at[d].add(m)

        def onehot(m, d):
            ohf = (d.reshape(tile, 1) == cols).astype(jnp.float32)
            return jnp.stack(
                [jnp.dot(m[:, i].reshape(1, tile), ohf,
                         preferred_element_type=jnp.float32).reshape(cl)
                 for i in range(m.shape[1])], axis=1)

        t_sc = _time(jax.jit(scatter), msg_l, dst_l)
        t_oh = _time(jax.jit(onehot), msg_l, dst_l)
        serial_ops = tile * lanes
        mxu_passes = lanes * ((tile + 127) // 128) * ((cl + 127) // 128)
        rows.append((
            f"kernels/lane_combine_scatter_E{tile}_C{cl}_L{lanes}", t_sc,
            f"{serial_ops} serial RMW scatter ops; HBM ~2*E*L accesses"))
        rows.append((
            f"kernels/lane_combine_onehot_E{tile}_C{cl}_L{lanes}", t_oh,
            f"{mxu_passes} MXU passes ({serial_ops / mxu_passes:.0f}x "
            f"fewer issue slots than scatter); HBM E+C*L={tile + cl * lanes}"
            f"; wall {t_sc / t_oh:.2f}x vs scatter"))
    # attention: chunked (the lowered path) vs full reference
    q = jnp.asarray(rng.normal(size=(1, 2048, 8, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 2048, 2, 64)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 2048, 2, 64)).astype(np.float32))
    rows.append(("kernels/attn_full_2k",
                 _time(jax.jit(lambda a, b_, c_: full_attention(a, b_, c_)),
                       q, k, v), "quadratic"))
    rows.append(("kernels/attn_chunked_2k",
                 _time(lambda a, b_, c_: chunked_attention(a, b_, c_),
                       q, k, v), "online-softmax (prefill path)"))
    # ssd: chunked vs naive scan
    x = jnp.asarray(rng.normal(size=(2, 1024, 8, 32)).astype(np.float32))
    a_log = jnp.asarray(rng.uniform(0, 2, size=(8,)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(2, 1024, 32)).astype(np.float32))
    cc = jnp.asarray(rng.normal(size=(2, 1024, 32)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(1e-3, 0.1, (2, 1024, 8)).astype(np.float32))
    rows.append(("kernels/ssd_scan_1k",
                 _time(jax.jit(ref.ssd_scan), x, a_log, b, cc, dt),
                 "naive recurrence"))
    rows.append(("kernels/ssd_chunked_1k",
                 _time(jax.jit(lambda *a: ssd_chunked(*a, chunk=128)),
                       x, a_log, b, cc, dt), "SSD chunked (model path)"))
    return rows
