"""Incremental mutation of the engine's edge state.

Two host-side structures cooperate, both living in the PERMUTED vertex
space of the current engine epoch:

  * :class:`EdgeStore` — the growable COO multiset of the BASE graph (the
    truth), bucketed per destination AND per source block so a dirty
    block's in-edge list (plus its mirror rows under symmetrization, and
    the out-neighbour lookup behind aux-dirty marking) can be re-gathered
    without a global sort or scan. Deletes are lazy (an alive mask);
    buckets compact opportunistically on gather, and the arrays themselves
    compact between batches once dead rows outnumber live ones.
  * :class:`MutableTiledState` — the mutable mirror of the engine's
    slack-padded :class:`TiledStorage`. Each block's live edges occupy a
    prefix of its flattened tile run, so a small insert APPENDS into the
    spare invalid slots in place; a block that loses edges (or whose
    in-edge set must be re-derived) is REBUILT from the EdgeStore truth —
    per-block, vectorised, never a global rebuild. Only when a block's
    tile run overflows its build-time capacity does the caller fall back
    to a full plan rebuild.

Symmetrized programs (CC) never match mirrored edge copies individually:
any block whose mirror in-edges could change is simply rebuilt from the
base truth (base rows by dst-bucket + mirrored rows by src-bucket), which
makes the incremental state equal ``symmetrize(mutated base)`` by
construction.
"""
from __future__ import annotations

import numpy as np

from repro.core.partition import TiledStorage


class EdgeStore:
    """Growable base-graph COO multiset in permuted ids + block buckets."""

    def __init__(self, psrc: np.ndarray, pdst: np.ndarray, w: np.ndarray,
                 n: int, num_blocks: int, block_size: int, symmetric: bool):
        m0 = int(psrc.size)
        cap = max(2 * m0, 1024)
        self.n = n
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.symmetric = symmetric
        self.psrc = np.zeros(cap, dtype=np.int64)
        self.pdst = np.zeros(cap, dtype=np.int64)
        self.w = np.zeros(cap, dtype=np.float32)
        self.alive = np.zeros(cap, dtype=bool)
        self.psrc[:m0] = psrc
        self.pdst[:m0] = pdst
        self.w[:m0] = w
        self.alive[:m0] = True
        self.m = m0  # high-water mark
        self.n_live = m0
        self.by_dst = self._bucket(self.pdst[:m0])
        # by-src buckets serve the symmetric mirror gather AND the
        # aux-dirty out-neighbour lookup, so they are always maintained
        self.by_src = self._bucket(self.psrc[:m0])

    def _bucket(self, keys: np.ndarray) -> list[np.ndarray]:
        order = np.argsort(keys // self.block_size, kind="stable")
        bounds = np.searchsorted(keys[order] // self.block_size,
                                 np.arange(self.num_blocks + 1))
        return [order[bounds[b]:bounds[b + 1]].astype(np.int64)
                for b in range(self.num_blocks)]

    def _grow(self, need: int) -> None:
        cap = self.psrc.size
        if self.m + need <= cap:
            return
        new_cap = max(2 * cap, self.m + need)
        for name in ("psrc", "pdst", "w", "alive"):
            a = getattr(self, name)
            b = np.zeros(new_cap, dtype=a.dtype)
            b[:self.m] = a[:self.m]
            setattr(self, name, b)

    def _bucket_live(self, buckets: list[np.ndarray],
                     b: int) -> np.ndarray:
        """Live ids of one bucket, compacting it in passing."""
        ids = buckets[b]
        ids = ids[self.alive[ids]]
        buckets[b] = ids
        return ids

    def kill_pairs(self, kpsrc: np.ndarray,
                   kpdst: np.ndarray) -> np.ndarray:
        """Mark ALL live copies of the given (src, dst) pairs dead; returns
        the killed copy ids (for degree / coupling / reset bookkeeping).
        Only the dst-buckets of the deleted pairs are scanned — O(edges of
        the touched blocks), not O(m)."""
        if kpsrc.size == 0 or self.m == 0:
            return np.empty(0, dtype=np.int64)
        dkeys = np.unique(kpsrc * self.n + kpdst)
        cand = [self._bucket_live(self.by_dst, int(b))
                for b in np.unique(kpdst // self.block_size)]
        cand = (np.concatenate(cand) if cand
                else np.empty(0, dtype=np.int64))
        keys = self.psrc[cand] * self.n + self.pdst[cand]
        ids = cand[np.isin(keys, dkeys)]
        self.alive[ids] = False
        self.n_live -= ids.size
        return ids

    def maybe_compact(self, max_dead_frac: float = 0.5) -> bool:
        """Reclaim dead rows once they outnumber the live ones: a
        long-lived engine under steady insert/delete churn must not grow
        its arrays (and its scan costs) without bound. Invalidates all
        previously-returned ids — call only between batches."""
        dead = self.m - self.n_live
        if self.m < 1024 or dead <= self.n_live * max_dead_frac:
            return False
        live = np.flatnonzero(self.alive[:self.m])
        k = live.size
        for name in ("psrc", "pdst", "w"):
            a = getattr(self, name)
            a[:k] = a[live]
        self.alive[:k] = True
        self.alive[k:self.m] = False
        self.m = k
        self.by_dst = self._bucket(self.pdst[:k])
        self.by_src = self._bucket(self.psrc[:k])
        return True

    def insert(self, ipsrc: np.ndarray, ipdst: np.ndarray,
               iw: np.ndarray) -> np.ndarray:
        """Append insert copies; returns their ids."""
        k = int(ipsrc.size)
        if k == 0:
            return np.empty(0, dtype=np.int64)
        self._grow(k)
        ids = np.arange(self.m, self.m + k, dtype=np.int64)
        self.psrc[ids] = ipsrc
        self.pdst[ids] = ipdst
        self.w[ids] = iw
        self.alive[ids] = True
        self.m += k
        self.n_live += k
        for buckets, keys in ((self.by_dst, ipdst),
                              (self.by_src, ipsrc)):
            kb = keys // self.block_size
            for b in np.unique(kb):
                buckets[int(b)] = np.concatenate(
                    [buckets[int(b)], ids[kb == b]])
        return ids

    def gather_block(self, b: int) -> tuple[np.ndarray, np.ndarray,
                                            np.ndarray]:
        """All live in-edges of block b as (src, dst_local, w) — base rows
        plus mirrored rows for symmetric engines. Compacts the buckets."""
        lo = b * self.block_size
        ids = self._bucket_live(self.by_dst, b)
        esrc, edst, ew = self.psrc[ids], self.pdst[ids], self.w[ids]
        if self.symmetric:
            mid = self._bucket_live(self.by_src, b)
            esrc = np.concatenate([esrc, self.pdst[mid]])
            edst = np.concatenate([edst, self.psrc[mid]])
            ew = np.concatenate([ew, self.w[mid]])
        return (esrc.astype(np.int32), (edst - lo).astype(np.int32), ew)

    def out_blocks_of(self, vertices: np.ndarray) -> np.ndarray:
        """Destination blocks of the live INTERNAL out-edges of the given
        vertices — the blocks whose aggregates silently change when those
        sources' aux (e.g. out-degree) changes. Scans only the buckets of
        the vertices' own blocks, not the whole edge set."""
        if vertices.size == 0:
            return np.empty(0, dtype=np.int64)
        c = self.block_size
        out: list[np.ndarray] = []
        for b in np.unique(vertices // c):
            ids = self._bucket_live(self.by_src, int(b))
            sel = ids[np.isin(self.psrc[ids], vertices)]
            if sel.size:
                out.append(self.pdst[sel] // c)
            if self.symmetric:
                # mirrored out-edges of v are its reversed base in-edges
                mid = self._bucket_live(self.by_dst, int(b))
                msel = mid[np.isin(self.pdst[mid], vertices)]
                if msel.size:
                    out.append(self.psrc[msel] // c)
        if not out:
            return np.empty(0, dtype=np.int64)
        return np.unique(np.concatenate(out))

    def live_base(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """The live base multiset (permuted ids)."""
        live = self.alive[:self.m]
        return (self.psrc[:self.m][live], self.pdst[:self.m][live],
                self.w[:self.m][live])


class MutableTiledState:
    """Mutable host mirror of one epoch's slack-padded TiledStorage.

    Invariant: block b's live edges occupy the first ``fill[b]`` slots of
    its flattened tile run ``[slot_lo[b], slot_lo[b] + cap[b])``; every
    other slot is masked invalid.
    """

    def __init__(self, store: TiledStorage):
        self.tile = store.tile
        self.num_blocks = store.num_blocks
        self.shape2d = store.src.shape
        self.src = store.src.reshape(-1).copy()
        self.dstl = store.dst_local.reshape(-1).copy()
        self.w = store.w.reshape(-1).copy()
        self.valid = store.valid.reshape(-1).copy()
        self.slot_lo = store.tile_start.astype(np.int64) * self.tile
        self.cap = store.tile_cnt.astype(np.int64) * self.tile
        self.fill = np.asarray(store.edges, dtype=np.int64).copy()

    def append(self, b: int, asrc: np.ndarray, adstl: np.ndarray,
               aw: np.ndarray) -> bool:
        """In-place append into block b's spare slots; False on overflow."""
        k = int(asrc.size)
        if self.fill[b] + k > self.cap[b]:
            return False
        lo = int(self.slot_lo[b] + self.fill[b])
        self.src[lo:lo + k] = asrc
        self.dstl[lo:lo + k] = adstl
        self.w[lo:lo + k] = aw
        self.valid[lo:lo + k] = True
        self.fill[b] += k
        return True

    def rebuild(self, b: int, esrc: np.ndarray, edstl: np.ndarray,
                ew: np.ndarray) -> bool:
        """Rewrite block b's whole tile run from truth; False on overflow."""
        k = int(esrc.size)
        if k > self.cap[b]:
            return False
        lo = int(self.slot_lo[b])
        self.src[lo:lo + k] = esrc
        self.dstl[lo:lo + k] = edstl
        self.w[lo:lo + k] = ew
        self.valid[lo:lo + k] = True
        self.valid[lo + k:lo + int(self.cap[b])] = False
        self.fill[b] = k
        return True

    def arrays2d(self) -> dict:
        """The device-upload view (same geometry as the compiled epoch)."""
        return {"src": self.src.reshape(self.shape2d),
                "dst_local": self.dstl.reshape(self.shape2d),
                "w": self.w.reshape(self.shape2d),
                "valid": self.valid.reshape(self.shape2d)}
