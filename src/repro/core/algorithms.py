"""Vertex programs: PR, CC, SSSP, BFS (+ BC driver in ``engine.bc``).

Each program supplies the pull-mode update and its *state degree* delta
(paper §3.3): PR uses Eq. 3 (|rank_curr - rank_next| accumulation), SSSP uses
Eq. 4 (the smaller of the two results, accumulated on change), CC the
max-analogue the paper describes.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.analysis.contracts import elementwise, structure_independent
from repro.core.graph import Graph

INF = np.float32(1e18)  # finite 'infinity': keeps inf-inf NaNs out of f32 math

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class VertexProgram:
    name: str
    combine: str  # 'sum' | 'min' | 'max'
    needs_symmetric: bool
    monotone_cooling: bool  # True -> barrier repartitioning is sound (PR-like)
    damping: float = 0.85
    # init(graph) -> (values (n,), aux (n,)); aux is per-vertex constant
    # data. Registered inits carry @structure_independent
    # (repro.analysis.contracts) — see that decorator for the normative
    # statement of why streaming delete-resets depend on it.
    init: Callable[[Graph], tuple[np.ndarray, np.ndarray]] = None
    # edge_map(src_val, src_aux, w) -> message
    edge_map: Callable[[Array, Array, Array], Array] = None
    # apply(old_block, agg_block, n_total) -> new_block
    apply: Callable[[Array, Array, int], Array] = None
    # sd_delta(old_block, new_block) -> nonnegative activity contribution
    sd_delta: Callable[[Array, Array], Array] = None
    # -- streaming hooks (repro.stream) -------------------------------------
    # aux_fn(out_deg, in_deg) -> aux: recompute the per-vertex constant
    # from incrementally-maintained degrees after an edge delta.
    # Registered aux_fns carry @elementwise (repro.analysis.contracts) —
    # the normative statement of the slicing the streaming engine does.
    # None => aux is degree-independent and survives mutation unchanged.
    aux_fn: Callable[[np.ndarray, np.ndarray], np.ndarray] | None = None
    # aux_delta(values, aux_old, aux_new) -> nonnegative per-edge bound on
    # |edge_map(v, aux_new, w) - edge_map(v, aux_old, w)| for the vertices
    # whose aux changed (subarray inputs). Lets the streaming engine turn
    # an aux change into a finite PSD bump on the affected blocks instead
    # of a full UNSEEN re-heat. None => affected blocks are marked dirty.
    aux_delta: Callable[..., np.ndarray] | None = None
    # reset_on_delete(g_new, values, del_src, del_dst, del_w) -> bool mask of
    # vertices whose values must be re-initialised before a warm re-start.
    # Needed for min/max programs: apply() can only improve a value, so a
    # deletion that breaks the supporting path would otherwise leave a stale
    # (too-good) value the iteration can never take back. None => the
    # program reconverges from any warm state (e.g. PageRank, whose apply
    # ignores the old value entirely).
    reset_on_delete: Callable[..., np.ndarray] | None = None
    # reset_on_delete_frontier(successors, n, values, del_src, del_dst,
    # del_w) -> the same mask, but served by a ``successors(frontier) ->
    # (src, dst, w)`` out-edge oracle instead of a built Graph, so the
    # streaming engine can answer it from the EdgeStore's by-src buckets
    # without rebuilding an O(m) CSR per delete batch.
    reset_on_delete_frontier: Callable[..., np.ndarray] | None = None

    @property
    def identity(self) -> np.float32:
        return {"sum": np.float32(0.0), "min": INF,
                "max": np.float32(-INF)}[self.combine]


def graph_successors(g: Graph, unit: bool = False) -> Callable[[np.ndarray],
                                                               tuple]:
    """``successors(frontier) -> (src, dst, w)`` oracle over a built Graph's
    CSR out-edges — the cold-path implementation of the interface
    :func:`_invalidated_by_delete` closes over (the streaming engine serves
    the same interface from its EdgeStore buckets instead). With ``unit``
    the weight gather is skipped (w is returned as None): unit-weight
    callers (BFS) overwrite it anyway."""
    indptr, out_dst, out_w = g.out_indptr, g.out_dst, g.out_w

    def successors(frontier: np.ndarray):
        starts, ends = indptr[frontier], indptr[frontier + 1]
        cnt = ends - starts
        total = int(cnt.sum())
        if total == 0:
            e = np.empty(0, dtype=np.int64)
            return e, e, np.empty(0, dtype=np.float64)
        eidx = (np.repeat(starts - np.concatenate(
            [[0], np.cumsum(cnt)[:-1]]), cnt) + np.arange(total))
        return (np.repeat(frontier, cnt), out_dst[eidx].astype(np.int64),
                None if unit else out_w[eidx].astype(np.float64))

    return successors


def _invalidated_by_delete(successors, n: int, dist: np.ndarray,
                           dsrc: np.ndarray, ddst: np.ndarray,
                           dw: np.ndarray, unit: bool = False) -> np.ndarray:
    """KickStarter-style delete trimming for min-combine distance programs:
    the set of vertices whose current distance may (transitively) depend on
    a deleted edge. Seeds are deletion heads whose old distance was achieved
    through the deleted copy; the set closes forward over edges of the NEW
    graph that were tight under the old distances, with the new graph's
    out-edges served by the ``successors(frontier) -> (src, dst, w)``
    oracle (a CSR via :func:`graph_successors`, or the streaming
    EdgeStore's by-src buckets). Over-approximate (a tie with an intact
    support still counts as dependent) — sound: every truly-unsupported
    vertex is included, extras just get recomputed. All vertices outside
    the mask keep distances that are still achieved by an intact path, so
    a warm min-combine re-run reconverges exactly."""
    d64 = np.asarray(dist, dtype=np.float64)
    dw = (np.ones(len(ddst)) if unit
          else np.asarray(dw, dtype=np.float64))
    reach = d64 < float(INF) / 2.0

    def tight(a, b, wab):  # b's value was (one of) a's relaxations
        return reach[a] & np.isclose(d64[b], d64[a] + wab,
                                     rtol=1e-5, atol=1e-4)

    mask = np.zeros(n, dtype=bool)
    dsrc = np.asarray(dsrc, dtype=np.int64)
    ddst = np.asarray(ddst, dtype=np.int64)
    mask[ddst[tight(dsrc, ddst, dw)]] = True
    if not mask.any():
        return mask
    # frontier-wise closure over the out-edges of newly-masked vertices
    # only: each vertex enters the frontier at most once, so the total work
    # is O(edges touched + n), not O(depth * m) (a deleted chain head would
    # otherwise rescan the whole edge set once per hop).
    frontier = np.flatnonzero(mask)
    while frontier.size:
        srcs, dsts, ws = successors(frontier)
        if srcs.size == 0:
            break
        if unit:
            ws = np.ones(srcs.size)
        hit = tight(srcs, dsts, ws) & ~mask[dsts]
        frontier = np.unique(dsts[hit])
        mask[frontier] = True
    return mask


def pagerank(damping: float = 0.85) -> VertexProgram:
    @structure_independent
    def init(g: Graph):
        vals = np.full(g.n, 1.0 / g.n, dtype=np.float32)
        aux = np.maximum(g.out_deg, 1).astype(np.float32)
        return vals, aux

    @elementwise
    def edge_map(src_val, src_aux, w):
        del w
        return src_val / src_aux

    @elementwise(shapes=((8,), (8,), "static"))
    def apply(old, agg, n_total):
        del old
        return (1.0 - damping) / n_total + damping * agg

    @elementwise
    def sd_delta(old, new):  # Eq. 3
        return jnp.abs(new - old)

    @elementwise
    def aux_fn(out_deg, in_deg):
        del in_deg
        return np.maximum(out_deg, 1).astype(np.float32)

    @elementwise
    def aux_delta(values, aux_old, aux_new):
        # edge_map is v / aux: the per-edge message change of a vertex whose
        # out-degree aux moved is exactly |v| * |1/old - 1/new|
        return np.abs(np.asarray(values, np.float64)) * np.abs(
            1.0 / np.asarray(aux_old, np.float64)
            - 1.0 / np.asarray(aux_new, np.float64))

    return VertexProgram(name="pagerank", combine="sum", needs_symmetric=False,
                         monotone_cooling=True, damping=damping, init=init,
                         edge_map=edge_map, apply=apply, sd_delta=sd_delta,
                         aux_fn=aux_fn, aux_delta=aux_delta)


def sssp(source: int = 0) -> VertexProgram:
    @structure_independent
    def init(g: Graph):
        vals = np.full(g.n, INF, dtype=np.float32)
        vals[source] = 0.0
        return vals, np.zeros(g.n, dtype=np.float32)

    @elementwise
    def edge_map(src_val, src_aux, w):
        del src_aux
        return src_val + w

    @elementwise(shapes=((8,), (8,), "static"))
    def apply(old, agg, n_total):
        del n_total
        return jnp.minimum(old, agg)

    @elementwise
    def sd_delta(old, new):  # Eq. 4: min of the two results, on change
        return jnp.where(new < old, jnp.minimum(new, old), 0.0)

    def reset_frontier(successors, n, values, dsrc, ddst, dw):
        return _invalidated_by_delete(successors, n, values, dsrc, ddst, dw,
                                      unit=False)

    def reset_on_delete(g, values, dsrc, ddst, dw):
        return reset_frontier(graph_successors(g), g.n, values, dsrc, ddst,
                              dw)

    return VertexProgram(name="sssp", combine="min", needs_symmetric=False,
                         monotone_cooling=False, init=init, edge_map=edge_map,
                         apply=apply, sd_delta=sd_delta,
                         reset_on_delete=reset_on_delete,
                         reset_on_delete_frontier=reset_frontier)


def bfs(source: int = 0) -> VertexProgram:
    @structure_independent
    def init(g: Graph):
        vals = np.full(g.n, INF, dtype=np.float32)
        vals[source] = 0.0
        return vals, np.zeros(g.n, dtype=np.float32)

    @elementwise
    def edge_map(src_val, src_aux, w):
        del src_aux, w
        return src_val + 1.0

    @elementwise(shapes=((8,), (8,), "static"))
    def apply(old, agg, n_total):
        del n_total
        return jnp.minimum(old, agg)

    @elementwise
    def sd_delta(old, new):
        return jnp.where(new < old, 1.0, 0.0)

    def reset_frontier(successors, n, values, dsrc, ddst, dw):
        return _invalidated_by_delete(successors, n, values, dsrc, ddst, dw,
                                      unit=True)

    def reset_on_delete(g, values, dsrc, ddst, dw):
        return reset_frontier(graph_successors(g, unit=True), g.n, values,
                              dsrc, ddst, dw)

    return VertexProgram(name="bfs", combine="min", needs_symmetric=False,
                         monotone_cooling=False, init=init, edge_map=edge_map,
                         apply=apply, sd_delta=sd_delta,
                         reset_on_delete=reset_on_delete,
                         reset_on_delete_frontier=reset_frontier)


def cc() -> VertexProgram:
    """Connected components via max-label propagation (paper: 'take a
    maximum'); requires the symmetrized graph."""

    @structure_independent
    def init(g: Graph):
        return np.arange(g.n, dtype=np.float32), np.zeros(g.n, np.float32)

    @elementwise
    def edge_map(src_val, src_aux, w):
        del src_aux, w
        return src_val

    @elementwise(shapes=((8,), (8,), "static"))
    def apply(old, agg, n_total):
        del n_total
        return jnp.maximum(old, agg)

    @elementwise
    def sd_delta(old, new):  # the larger of the two results, on change
        return jnp.where(new > old, jnp.maximum(new, old), 0.0)

    def _label_reset(values, dsrc, ddst):
        # a deletion can split the component both endpoints sit in: re-flood
        # every vertex carrying that component's label from its own id.
        # Other components are untouched (labels never cross components).
        labels = np.unique(np.concatenate(
            [np.asarray(values)[np.asarray(dsrc, dtype=np.int64)],
             np.asarray(values)[np.asarray(ddst, dtype=np.int64)]]))
        return np.isin(np.asarray(values), labels)

    def reset_on_delete(g, values, dsrc, ddst, dw):
        del g, dw
        return _label_reset(values, dsrc, ddst)

    def reset_frontier(successors, n, values, dsrc, ddst, dw):
        # the label rule needs no graph traversal at all — exposing it as a
        # frontier hook just keeps the streaming engine off the
        # build-a-CSR fallback path
        del successors, n, dw
        return _label_reset(values, dsrc, ddst)

    return VertexProgram(name="cc", combine="max", needs_symmetric=True,
                         monotone_cooling=False, init=init, edge_map=edge_map,
                         apply=apply, sd_delta=sd_delta,
                         reset_on_delete=reset_on_delete,
                         reset_on_delete_frontier=reset_frontier)


REGISTRY: dict[str, Callable[..., VertexProgram]] = {
    "pagerank": pagerank,
    "sssp": sssp,
    "bfs": bfs,
    "cc": cc,
}


# -- multi-lane programs (repro.serve) ---------------------------------------
@dataclasses.dataclass(frozen=True)
class LaneProgram:
    """A *family* of per-source queries executed as lanes of one run.

    The lane generalization of :class:`VertexProgram`: vertex values carry
    a trailing lane axis ``(n, L)`` and one engine sweep advances every
    lane at once — the per-block edge slice is gathered once and the
    messages/aggregates are ``(E, L)`` / ``(C, L)`` instead of ``(E,)`` /
    ``(C,)``. Everything per-lane (the query's source, a personalized
    restart vector) lives in DATA — the init values and the optional
    per-vertex ``vconst`` matrix are traced *arguments* of the compiled
    lane superstep, never closure constants — so one compiled executable
    serves every batch of the same family at the same lane width.

    ``lane_init(n, params)`` builds that data on the host: ``params`` is
    one entry per lane (a source id, or a personalization set) and the
    result is ``(values (n, L) float32, vconst (n, L) float32 | None)`` in
    ORIGINAL vertex ids. Registered lane_inits carry
    @structure_independent (repro.analysis.contracts) — the normative
    statement — because query lanes run over an epoch snapshot whose
    degrees are maintained incrementally.

    ``aux_fn(out_deg, in_deg)`` supplies the family's per-vertex constant
    from the snapshot's degree arrays; registered aux_fns carry
    @elementwise, same as ``VertexProgram.aux_fn``. None means the family
    ignores aux.
    """

    name: str
    combine: str  # 'sum' | 'min' | 'max'
    needs_symmetric: bool
    monotone_cooling: bool
    uses_vconst: bool
    damping: float = 0.85
    # lane_init(n, params) -> (values (n, L), vconst (n, L) | None)
    lane_init: Callable[[int, list], tuple[np.ndarray,
                                           np.ndarray | None]] = None
    # edge_map(src_vals (E, L), src_aux (E,), w (E,)) -> (E, L)
    edge_map: Callable[[Array, Array, Array], Array] = None
    # apply(old (C, L), agg (C, L), vconst (C, L), n_total) -> (C, L)
    apply: Callable[[Array, Array, Array, int], Array] = None
    # sd_delta(old (C, L), new (C, L)) -> nonnegative (C, L)
    sd_delta: Callable[[Array, Array], Array] = None
    aux_fn: Callable[[np.ndarray, np.ndarray], np.ndarray] | None = None

    @property
    def identity(self) -> np.float32:
        return {"sum": np.float32(0.0), "min": INF,
                "max": np.float32(-INF)}[self.combine]


def _source_lane_values(n: int, sources: list) -> np.ndarray:
    vals = np.full((n, len(sources)), INF, dtype=np.float32)
    for lane, s in enumerate(sources):
        if not 0 <= int(s) < n:
            raise ValueError(f"lane source {s} out of range [0, {n})")
        vals[int(s), lane] = 0.0
    return vals


def k_source_sssp() -> LaneProgram:
    """L independent single-source shortest-path queries per sweep."""

    @structure_independent
    def lane_init(n, sources):
        return _source_lane_values(n, sources), None

    @elementwise(shapes=((8, 4), (8,), (8,)))
    def edge_map(src_vals, src_aux, w):
        del src_aux
        return src_vals + w[:, None]

    @elementwise(shapes=((8, 4), (8, 4), (8, 4), "static"))
    def apply(old, agg, vconst, n_total):
        del vconst, n_total
        return jnp.minimum(old, agg)

    @elementwise(shapes=((8, 4), (8, 4)))
    def sd_delta(old, new):  # Eq. 4 per lane
        return jnp.where(new < old, jnp.minimum(new, old), 0.0)

    return LaneProgram(name="k_sssp", combine="min", needs_symmetric=False,
                       monotone_cooling=False, uses_vconst=False,
                       lane_init=lane_init, edge_map=edge_map, apply=apply,
                       sd_delta=sd_delta)


def k_source_bfs() -> LaneProgram:
    """L independent BFS (unit-weight distance) queries per sweep."""

    @structure_independent
    def lane_init(n, sources):
        return _source_lane_values(n, sources), None

    @elementwise(shapes=((8, 4), (8,), (8,)))
    def edge_map(src_vals, src_aux, w):
        del src_aux, w
        return src_vals + 1.0

    @elementwise(shapes=((8, 4), (8, 4), (8, 4), "static"))
    def apply(old, agg, vconst, n_total):
        del vconst, n_total
        return jnp.minimum(old, agg)

    @elementwise(shapes=((8, 4), (8, 4)))
    def sd_delta(old, new):
        return jnp.where(new < old, 1.0, 0.0)

    return LaneProgram(name="k_bfs", combine="min", needs_symmetric=False,
                       monotone_cooling=False, uses_vconst=False,
                       lane_init=lane_init, edge_map=edge_map, apply=apply,
                       sd_delta=sd_delta)


def k_personalized_pagerank(damping: float = 0.85) -> LaneProgram:
    """L personalized-PageRank queries per sweep: lane l restarts into its
    own distribution r_l (``vconst`` column l) instead of the uniform
    vector — v_l = (1-d) r_l + d A v_l. A lane's param is either a dense
    (n,) distribution or a set of vertex ids (uniform over the set).
    Dangling mass vanishes exactly as in the registered ``pagerank``
    program (aux = max(out_deg, 1))."""

    @structure_independent
    def lane_init(n, resets):
        r = np.zeros((n, len(resets)), dtype=np.float32)
        for lane, rs in enumerate(resets):
            rs = np.asarray(rs)
            if rs.ndim == 1 and rs.size == n and rs.dtype.kind == "f":
                col = rs.astype(np.float64)
                if not np.isclose(col.sum(), 1.0, rtol=1e-4):
                    raise ValueError("dense reset must sum to 1")
                r[:, lane] = col.astype(np.float32)
            else:
                ids = rs.astype(np.int64).reshape(-1)
                if ids.size == 0 or ids.min() < 0 or ids.max() >= n:
                    raise ValueError("reset set must be non-empty vertex "
                                     f"ids in [0, {n})")
                # np.add.at, not fancy-indexed +=: a repeated id must
                # accumulate its full share or the restart mass silently
                # shrinks below 1
                np.add.at(r[:, lane], ids, np.float32(1.0 / ids.size))
        # start at the restart vector: the fixpoint's (1-d) r term is
        # already in place, so warm-ish convergence from lane data alone
        return r.copy(), r

    @elementwise(shapes=((8, 4), (8,), (8,)))
    def edge_map(src_vals, src_aux, w):
        del w
        return src_vals / src_aux[:, None]

    @elementwise(shapes=((8, 4), (8, 4), (8, 4), "static"))
    def apply(old, agg, vconst, n_total):
        del old, n_total
        return (1.0 - damping) * vconst + damping * agg

    @elementwise(shapes=((8, 4), (8, 4)))
    def sd_delta(old, new):  # Eq. 3 per lane
        return jnp.abs(new - old)

    @elementwise
    def aux_fn(out_deg, in_deg):
        del in_deg
        return np.maximum(out_deg, 1).astype(np.float32)

    return LaneProgram(name="k_ppr", combine="sum", needs_symmetric=False,
                       monotone_cooling=True, uses_vconst=True,
                       damping=damping, lane_init=lane_init,
                       edge_map=edge_map, apply=apply, sd_delta=sd_delta,
                       aux_fn=aux_fn)


LANE_FAMILIES: dict[str, Callable[..., LaneProgram]] = {
    "sssp": k_source_sssp,
    "bfs": k_source_bfs,
    "ppr": k_personalized_pagerank,
}
