"""Graph container + generators.

Host-side (numpy) preprocessing, exactly like production graph systems: the
one-time CSR/CSC build and the activity-based vertex permutation (paper §3.2,
"the time of reordering graph vertices is once in the whole algorithmic
process") happen on the host; the iterate runs on device.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Graph:
    """Directed graph in CSR (out-edges) + CSC (in-edges) form.

    ``in_src``/``in_w`` are sorted by destination, i.e. the in-edges of vertex
    ``v`` occupy ``in_src[in_indptr[v]:in_indptr[v+1]]``. This is the pull-mode
    layout the engine slices per partition (contiguous after permutation).
    """

    n: int
    m: int
    out_indptr: np.ndarray  # (n+1,) int64
    out_dst: np.ndarray  # (m,) int32
    out_w: np.ndarray  # (m,) float32, CSR order
    in_indptr: np.ndarray  # (n+1,) int64
    in_src: np.ndarray  # (m,) int32, CSC order
    in_w: np.ndarray  # (m,) float32, CSC order

    @property
    def out_deg(self) -> np.ndarray:
        return np.diff(self.out_indptr).astype(np.int64)

    @property
    def in_deg(self) -> np.ndarray:
        return np.diff(self.in_indptr).astype(np.int64)


def from_edges(n: int, src: np.ndarray, dst: np.ndarray,
               w: np.ndarray | None = None) -> Graph:
    """Build CSR+CSC from a COO edge list (duplicates kept, self-loops kept)."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    m = src.shape[0]
    if w is None:
        w = np.ones(m, dtype=np.float32)
    w = np.asarray(w, dtype=np.float32)

    # CSR: sort by src.
    order = np.argsort(src, kind="stable")
    csr_dst = dst[order].astype(np.int32)
    csr_w = w[order]
    out_indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(out_indptr, src + 1, 1)
    out_indptr = np.cumsum(out_indptr)

    # CSC: sort by dst.
    order = np.argsort(dst, kind="stable")
    csc_src = src[order].astype(np.int32)
    csc_w = w[order]
    in_indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(in_indptr, dst + 1, 1)
    in_indptr = np.cumsum(in_indptr)

    return Graph(n=n, m=m, out_indptr=out_indptr, out_dst=csr_dst, out_w=csr_w,
                 in_indptr=in_indptr, in_src=csc_src, in_w=csc_w)


def edges_of(g: Graph) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """COO (src, dst, w) in CSC order."""
    dst = np.repeat(np.arange(g.n, dtype=np.int64), g.in_deg)
    return g.in_src.astype(np.int64), dst, g.in_w


def symmetrize(g: Graph) -> Graph:
    """Union of edges with their reverses (for CC / undirected semantics)."""
    s, d, w = edges_of(g)
    src = np.concatenate([s, d])
    dst = np.concatenate([d, s])
    ww = np.concatenate([w, w])
    return from_edges(g.n, src, dst, ww)


def powerlaw_graph(n: int, avg_deg: int = 8, seed: int = 0,
                   zipf_a: float = 1.2, weighted: bool = False) -> Graph:
    """Skewed 'small-world' graph (paper §3: power-function degree law).

    Destinations are Zipf-distributed over a random vertex ranking, so a few
    hub vertices collect most in-edges; sources are uniform. This reproduces
    the hot/cold structure the paper exploits (celebrity/follower example).
    """
    rng = np.random.default_rng(seed)
    m = n * avg_deg
    rank = rng.permutation(n)
    # Zipf weights over ranks; normalize to a categorical.
    p = 1.0 / np.power(np.arange(1, n + 1, dtype=np.float64), zipf_a)
    p /= p.sum()
    dst = rank[rng.choice(n, size=m, p=p)]
    src = rng.integers(0, n, size=m)
    w = rng.uniform(0.1, 1.0, size=m).astype(np.float32) if weighted else None
    return from_edges(n, src, dst, w)


def core_periphery_graph(n: int, avg_deg: int = 8, seed: int = 0,
                         core_frac: float = 0.02, chords: int = 2,
                         weighted: bool = False) -> Graph:
    """Power-law graph with a *slow-mixing hub core* — the convergence-skew
    regime the paper's real datasets (twitter-2010, WikiTalk) exhibit.

    Periphery edges are Zipf-directed into the hub ids, so the core has huge
    in-degree (AD marks it hot). The core itself is a directed ring with a
    few chords: residual rank mass circulates around the ring and decays only
    at the damping rate per hop (a random dense core would mix at lambda_2 ~
    1/sqrt(deg) and converge almost immediately). Result: the periphery
    settles in a few sweeps while the hot core needs ~log(T2)/log(d) more —
    a structure-unaware system keeps sweeping ALL partitions until the core
    settles (the paper's Figure 1); a structure-aware one re-processes only
    the couple of hot blocks.
    """
    rng = np.random.default_rng(seed)
    n_core = max(int(n * core_frac), 4)
    # periphery -> Zipf-favoured dsts (ids 0..n_core are the hubs)
    m_per = n * avg_deg
    p = 1.0 / np.power(np.arange(1, n + 1, dtype=np.float64), 1.2)
    p /= p.sum()
    dst = rng.choice(n, size=m_per, p=p)
    # a fraction of follows go uniformly to the hubs (celebrities draw
    # followers throughout), giving every core vertex clearly-top in-degree
    # so the AD sort packs the core into few contiguous blocks
    boost = rng.random(m_per) < 0.3
    dst[boost] = rng.integers(0, n_core, size=int(boost.sum()))
    # sources live strictly in the periphery: hub out-edges are ONLY the
    # ring, so residual mass cannot leak out of the slow-mixing core
    src = rng.integers(n_core, n, size=m_per)
    # slow-mixing core: hub i -> hubs i+1 .. i+chords (mod n_core)
    core_src = np.repeat(np.arange(n_core, dtype=np.int64), chords)
    core_off = np.tile(np.arange(1, chords + 1, dtype=np.int64), n_core)
    core_dst = (core_src + core_off) % n_core
    src = np.concatenate([src, core_src])
    dst = np.concatenate([dst, core_dst])
    m = src.shape[0]
    w = rng.uniform(0.1, 1.0, size=m).astype(np.float32) if weighted else None
    return from_edges(n, src, dst, w)


def uniform_graph(n: int, deg: int = 4, seed: int = 0,
                  weighted: bool = False) -> Graph:
    """Road-network-like graph: even degree distribution, local neighbours.

    Each vertex links to ``deg`` vertices within a small index window (plus a
    wraparound), giving the 'even in/out-edge distribution' regime where the
    paper says alpha -> 0.5.
    """
    rng = np.random.default_rng(seed)
    src = np.repeat(np.arange(n, dtype=np.int64), deg)
    offs = rng.integers(1, 64, size=n * deg)
    dst = (src + offs) % n
    w = rng.uniform(0.1, 1.0, size=n * deg).astype(np.float32) if weighted else None
    return from_edges(n, src, dst, w)


def chain_graph(n: int, weighted: bool = False) -> Graph:
    """Path 0 -> 1 -> ... -> n-1 (oracle-friendly)."""
    src = np.arange(n - 1, dtype=np.int64)
    dst = src + 1
    w = (np.arange(1, n, dtype=np.float32) % 5 + 1.0) if weighted else None
    return from_edges(n, src, dst, w)


def _open_text(path: str):
    if str(path).endswith(".gz"):
        import gzip
        return gzip.open(path, "rt")
    return open(path, "r")


def parse_coo(path: str) -> tuple[np.ndarray, np.ndarray,
                                  np.ndarray | None]:
    """Parse a whitespace 'src dst [w]' edge-list file (SNAP-style; ``.gz``
    accepted) into (src, dst, w|None).

    Vertex ids are parsed as int64 END TO END — routing them through
    float64 (as ``np.loadtxt(dtype=float)`` would) silently corrupts ids
    above 2**53, which real SNAP crawls (hashed ids) do contain. Memory
    stays at the numpy-array level: loadtxt streams the file, and a
    ``.gz`` input is decompressed exactly once (to a temp file) rather
    than per parsing pass.
    """
    import os
    import shutil
    import tempfile

    def parse(opener):
        with opener() as f:
            ncols = 0
            for lineno, line in enumerate(f, 1):
                # strip inline trailing comments the same way loadtxt's
                # comments=('#', '%') does, so the column probe agrees
                # with the parsing passes
                t = line.split("#")[0].split("%")[0].strip()
                if not t:
                    continue
                k = len(t.split())
                if ncols == 0:
                    ncols = k
                elif k != ncols:
                    # loadtxt(usecols=...) would silently accept ragged
                    # rows (dropping weights); fail loudly instead
                    raise ValueError(
                        f"{path}:{lineno}: inconsistent column count "
                        f"({k} vs {ncols})")
        if ncols == 0:
            raise ValueError(f"{path}: no edges found")
        if ncols < 2:
            raise ValueError(f"{path}: expected 'src dst [w]' rows, got "
                             f"{ncols} column(s)")
        with opener() as f:
            ids = np.loadtxt(f, dtype=np.int64, usecols=(0, 1),
                             comments=("#", "%"), ndmin=2)
        w = None
        if ncols > 2:
            with opener() as f:
                w = np.loadtxt(f, dtype=np.float64, usecols=(2,),
                               comments=("#", "%"),
                               ndmin=1).astype(np.float32)
        return ids, w

    if str(path).endswith(".gz"):
        # decompress once into a temp dir and reopen by path (re-opening a
        # live NamedTemporaryFile by name is not portable to Windows)
        with tempfile.TemporaryDirectory() as d:
            plain = os.path.join(d, "edges.coo")
            with _open_text(path) as f, open(plain, "w") as out:
                shutil.copyfileobj(f, out)
            ids, w = parse(lambda: open(plain, "r"))
    else:
        ids, w = parse(lambda: open(path, "r"))
    if ids.size and ids.min() < 0:
        bad = ids[ids < 0].flat[0]
        raise ValueError(f"{path}: negative vertex id {bad} — edge lists "
                         "must use non-negative integer ids")
    return ids[:, 0], ids[:, 1], w


def load_coo(path: str, n: int | None = None) -> Graph:
    """Load a whitespace 'src dst [w]' edge-list file (SNAP-style, plain or
    gzip'd) with exact integer id parsing."""
    src, dst, w = parse_coo(path)
    if n is None:
        n = int(max(src.max(), dst.max())) + 1
    return from_edges(n, src, dst, w)


def permute(g: Graph, order: np.ndarray) -> tuple[Graph, np.ndarray]:
    """Relabel vertices so that new id ``i`` is old vertex ``order[i]``.

    Returns the permuted graph and ``inv`` with ``inv[old] = new`` (use it to
    map results back).
    """
    inv = np.empty(g.n, dtype=np.int64)
    inv[order] = np.arange(g.n, dtype=np.int64)
    s, d, w = edges_of(g)
    return from_edges(g.n, inv[s], inv[d], w), inv
