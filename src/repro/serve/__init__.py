"""Query-serving subsystem: batched multi-source sessions over a live
streaming graph (lanes, epoch pinning, PSD-priority admission)."""
from repro.core.algorithms import (LANE_FAMILIES, LaneProgram, k_source_bfs,
                                   k_source_sssp, k_personalized_pagerank)
from repro.serve.lanes import LaneEngine, LaneResult
from repro.serve.service import Query, QueryResult, QueryService

__all__ = [
    "LANE_FAMILIES", "LaneProgram", "k_source_bfs", "k_source_sssp",
    "k_personalized_pagerank", "LaneEngine", "LaneResult", "Query",
    "QueryResult", "QueryService",
]
