"""Quickstart: the paper's structure-aware engine vs the Gemini-style
baseline on a convergence-skewed power-law graph (PageRank).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import algorithms as A
from repro.core import graph as G
from repro.core.baseline import BaselineEngine
from repro.core.engine import EngineConfig, StructureAwareEngine


def main():
    g = G.core_periphery_graph(20000, avg_deg=8, seed=1, chords=1)
    prog = A.pagerank()
    cfg = EngineConfig(t2=1e-9, width=16, block_size=512)

    base = BaselineEngine(g, prog, cfg, frontier=False).run()
    sa = StructureAwareEngine(g, prog, cfg).run()

    assert np.allclose(base.values, sa.values, rtol=1e-4, atol=1e-7), \
        "engines disagree!"
    print(f"{'':14s}{'iters':>8s}{'updates':>12s}{'loads':>8s}{'MB':>10s}")
    for name, r in [("baseline", base), ("structure-aware", sa)]:
        m = r.metrics
        print(f"{name:14s}{m.iterations:8d}{m.updates:12d}"
              f"{m.block_loads:8d}{m.bytes_loaded/1e6:10.1f}")
    m0, m1 = base.metrics, sa.metrics
    print(f"\nstructure-aware gain: {m0.updates/m1.updates:.2f}x fewer "
          f"updates, {m0.block_loads/m1.block_loads:.2f}x fewer partition "
          f"loads, {m0.bytes_loaded/m1.bytes_loaded:.2f}x less I/O")


if __name__ == "__main__":
    main()
