"""Production mesh builders.

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).

Topology (TPU v5e): one pod = 16x16 = 256 chips; multi-pod = 2 pods over
DCN. Axes: "pod" (DCN, slow) > "data" (DP / ZeRO) > "model" (TP/EP/SP).

Compat: ``jax.sharding.AxisType`` only exists on newer jax (>= 0.5); on the
pinned 0.4.x every mesh axis already behaves like ``Auto``, so the builders
simply omit the kwarg there.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5
    from jax.sharding import AxisType
except ImportError:  # jax 0.4.x: meshes are implicitly all-Auto
    AxisType = None


def _axis_kwargs(n_axes: int) -> dict:
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}


def make_mesh(shape, axes):
    """``jax.make_mesh`` with explicit Auto axis types when supported."""
    return jax.make_mesh(shape, axes, **_axis_kwargs(len(axes)))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """All local devices -> ("data", "model") mesh (tests / CPU training)."""
    n = len(jax.devices())
    assert n % model == 0
    return make_mesh((n // model, model), ("data", "model"))


def batch_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
