"""Streaming-graph subsystem: edge-delta ingestion over the structure-aware
engine (dirty-block re-heat = the universal repartitioner's cold->hot path,
applied to graph mutation instead of in-run decay)."""
from repro.stream.delta import DeltaBatch, synthetic_stream
from repro.stream.engine import (StreamBatchReport, StreamConfig,
                                 StreamingEngine)

__all__ = ["DeltaBatch", "synthetic_stream", "StreamBatchReport",
           "StreamConfig", "StreamingEngine"]
