"""Structure-aware iteration driver (paper §3–§4, Algorithms 1–3).

The engine executes one vertex program over a :class:`PartitionPlan`:

  * hot-labelled blocks run **sequentially** within an iteration (the paper's
    asynchronous mode — each block sees the freshest values, Maiter-style
    delta propagation through the hubs);
  * cold-labelled blocks run **batched** from a post-hot snapshot (the
    paper's synchronous mode);
  * the scheduler picks the top-PSD m hot + n cold blocks per iteration
    (Alg. 3) and the repartitioner re-labels blocks on a growing cadence
    (Alg. 2);
  * convergence is SUM_j PSD(j) < T2 (§4), with unvisited blocks carrying an
    UNSEEN sentinel so the whole graph is covered at least once.

Correctness beyond the paper's prose: partial scheduling needs a staleness
signal — when block j's vertices change, downstream blocks (containing j's
out-neighbours) must become schedulable again even if their own PSD already
decayed to 0 (the paper's 'cold partitions can re-heat'). We precompute the
block->affected-blocks adjacency once (host, O(m)) and bump downstream PSDs
after each iteration. Without this, min/max programs can terminate with
stale values; with it, every engine run reaches the same fixpoint as the
synchronous baseline (tested property).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import state as state_lib
from repro.core.algorithms import VertexProgram
from repro.core.graph import Graph, symmetrize
from repro.core.metrics import Metrics, Timer
from repro.core.partition import EdgeStorage, PartitionPlan, build_plan
from repro.core.repartition import RepartitionState
from repro.core.schedule import Scheduler, Selection


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    block_size: int = 256
    width: int = 8  # W = m + n (paper: worker count)
    i2: int = 4  # cold-admission cadence (paper I2)
    cold_frac: float = 0.25  # n/W; paper requires m > n
    repartition_interval: int = 4  # paper I1 (grows over time)
    repartition_growth: float = 1.5
    hot_inner_iters: int = 8  # async hot mode: block-local Gauss-Seidel
    hot_ratio: float = 0.1
    sample_frac: float = 0.1
    alpha: float | None = None  # Eq. 1 alpha; None -> suggest_alpha
    t2: float = 1e-6  # paper's default convergence threshold
    max_iterations: int = 100000
    stale_eps: float = 1e-12  # PSD above this marks downstream blocks dirty
    use_pallas: bool = False  # sum-combine via the Pallas spmv kernel
    seed: int = 0


@dataclasses.dataclass
class RunResult:
    values: np.ndarray  # indexed by ORIGINAL vertex id
    metrics: Metrics
    history: list  # per-iteration dicts (for convergence curves)


def _combine_local(program: VertexProgram, msg, dst_local, block_size,
                   use_pallas: bool):
    if program.combine == "sum":
        if use_pallas:
            from repro.kernels import ops as kops
            return kops.edge_block_sum(msg, dst_local, block_size)
        return jnp.zeros(block_size, jnp.float32).at[dst_local].add(msg)
    if program.combine == "min":
        return jnp.full(block_size, program.identity).at[dst_local].min(msg)
    return jnp.full(block_size, program.identity).at[dst_local].max(msg)


def make_block_processor(program: VertexProgram, store: EdgeStorage, aux,
                         block_size: int, n_live: int, n_total: int,
                         use_pallas: bool):
    """Returns (process_one, gids): the pull-mode update for one block row of
    one storage group. Shared by the local and shard_map engines."""
    src = jnp.asarray(store.src)
    dstl = jnp.asarray(store.dst_local)
    ew = jnp.asarray(store.w)
    evalid = jnp.asarray(store.valid)
    gids = jnp.asarray(store.block_ids, dtype=jnp.int32)
    c = block_size

    def process_one(values, row):
        e_src = src[row]
        msg = program.edge_map(values[e_src], aux[e_src], ew[row])
        msg = jnp.where(evalid[row], msg, program.identity)
        agg = _combine_local(program, msg, dstl[row], c, use_pallas)
        base = gids[row] * c
        old = lax.dynamic_slice(values, (base,), (c,))
        new = program.apply(old, agg, n_total)
        vmask = (base + jnp.arange(c)) < n_live
        new = jnp.where(vmask, new, old)
        delta = jnp.where(vmask, program.sd_delta(old, new), 0.0)
        cnt = jnp.maximum(vmask.sum(), 1)
        # (mean, max) per-block deltas: mean is the paper's PSD; max feeds the
        # sound staleness bound (mean-based coupling under-estimates when the
        # delta mass is concentrated on a hub).
        return base, new, delta.sum() / cnt, delta.max()

    def process_iterated(values, row, t_inner):
        """Asynchronous hot mode, TPU-native: the block's edge slice is
        VMEM-resident, so re-applying the block update t_inner times costs
        ONE partition load but advances intra-block dependency chains
        t_inner hops (the paper's per-vertex async propagation, at block
        granularity). Writes only within the block's own range."""
        base = gids[row] * c
        old = lax.dynamic_slice(values, (base,), (c,))

        def inner(_, vals):
            _, new, _, _ = process_one(vals, row)
            return lax.dynamic_update_slice(vals, new, (base,))

        vals2 = lax.fori_loop(0, t_inner, inner, values)
        newb = lax.dynamic_slice(vals2, (base,), (c,))
        vmask = (base + jnp.arange(c)) < n_live
        delta = jnp.where(vmask, program.sd_delta(old, newb), 0.0)
        cnt = jnp.maximum(vmask.sum(), 1)
        return base, newb, delta.sum() / cnt, delta.max()

    return process_one, process_iterated, gids


class StructureAwareEngine:
    """Paper pipeline: build plan -> iterate (schedule, process, repartition)."""

    def __init__(self, graph: Graph, program: VertexProgram,
                 config: EngineConfig = EngineConfig()):
        self.program = program
        self.config = config
        g = symmetrize(graph) if program.needs_symmetric else graph
        self.plan = build_plan(
            g, block_size=config.block_size, alpha=config.alpha,
            sample_frac=config.sample_frac, hot_ratio=config.hot_ratio,
            seed=config.seed)
        vals0, aux0 = program.init(g)  # original ids ...
        self.values0 = vals0[self.plan.order]  # ... permuted to plan order
        self.aux = jnp.asarray(aux0[self.plan.order])
        self._init_dead()
        # Pad the value vector so every block's (base, block_size) slice is
        # in-bounds: lax.dynamic_slice CLAMPS out-of-range starts, which would
        # silently corrupt the last block's writes.
        p = self.plan
        self._values_len = max(p.num_blocks * p.block_size, p.graph.n)
        pad = self._values_len - p.graph.n
        if pad:
            self.values0 = np.concatenate(
                [self.values0, np.zeros(pad, dtype=self.values0.dtype)])
        self._block_affects = self._build_block_affects()
        self._coupling = self._build_coupling_matrix()
        self._post = jax.jit(self._make_post())
        self._fns: dict = {}

    # -- one-time host preprocessing ---------------------------------------
    def _init_dead(self):
        """Dead partition: processed once at start (§3.2) — apply() with the
        identity aggregate, after which these vertices are final."""
        p = self.plan
        if p.n_dead == 0:
            return
        dead = slice(p.n_live, p.graph.n)
        old = jnp.asarray(self.values0[dead])
        agg = jnp.full(p.n_dead, 0.0 if self.program.combine == "sum"
                       else self.program.identity, jnp.float32)
        self.values0 = np.array(self.values0)
        self.values0[dead] = np.asarray(
            self.program.apply(old, agg, p.graph.n))

    def _build_block_affects(self) -> list[tuple[np.ndarray, np.ndarray]]:
        """block j -> (target blocks, coupling weights).

        Soundness: with v = MAX per-vertex delta in block j, the delta mass
        entering block b is <= v * sum_{u in j} min(edges(u->b)/outdeg(u), 1)
        <= v * min(W_jb, C_j), so b's mean-PSD can move by at most
        decay * v * min(W_jb, C) / C. For min/max programs improvements
        propagate undiminished and unsplit, so the coupling is 1 on every
        reachable target (correctness over tightness)."""
        p = self.plan
        g = p.graph
        c = p.block_size
        mass_like = self.program.combine == "sum"
        out: list[tuple[np.ndarray, np.ndarray]] = []
        for b in range(p.num_blocks):
            lo, hi = p.block_range(b)
            dsts = g.out_dst[g.out_indptr[lo]:g.out_indptr[hi]]
            blocks, counts = np.unique(dsts // c, return_counts=True)
            keep = blocks < p.num_blocks
            blocks, counts = blocks[keep], counts[keep]
            if mass_like:
                wts = (np.minimum(counts, c) / c).astype(np.float32)
            else:
                wts = np.ones(blocks.size, dtype=np.float32)
            out.append((blocks.astype(np.int64), wts))
        return out

    def _build_coupling_matrix(self) -> np.ndarray:
        """Dense (P, P) staleness-coupling matrix (decay folded in): the
        device-side bump is the max-product matvec
        ``bump_b = max_j dmax_j * K[j, b]``."""
        p = self.plan
        decay = (self.program.damping if self.program.combine == "sum"
                 else 1.0)
        k = np.zeros((p.num_blocks, p.num_blocks), dtype=np.float32)
        for j, (tgt, wts) in enumerate(self._block_affects):
            k[j, tgt] = wts * decay
        return k

    def _make_post(self):
        coupling = jnp.asarray(self._coupling)
        eps = self.config.stale_eps

        def post(psd, dmax):
            """Consume dmax: re-arm downstream blocks, then reset."""
            d = jnp.where(dmax > eps, dmax, 0.0)
            bump = jnp.max(d[:, None] * coupling, axis=0)
            psd = jnp.maximum(psd, jnp.minimum(bump, 1e29))
            return psd, jnp.zeros_like(dmax)
        return post

    # -- jitted block processing -------------------------------------------
    def _get_fn(self, store_key: str, sequential: bool) -> Callable:
        key = (store_key, sequential)
        if key in self._fns:
            return self._fns[key]
        store: EdgeStorage = getattr(self.plan, store_key)
        program, cfg, plan = self.program, self.config, self.plan
        c = plan.block_size
        width = cfg.width
        t_inner = max(cfg.hot_inner_iters, 1)
        process_one, process_iterated, gids = make_block_processor(
            program, store, self.aux, c, plan.n_live, plan.graph.n,
            cfg.use_pallas)

        def write_one(values, psd, dmax, base, new, psd_val, dmax_val, gid,
                      ok):
            cur = lax.dynamic_slice(values, (base,), (c,))
            values = lax.dynamic_update_slice(
                values, jnp.where(ok, new, cur), (base,))
            psd = jnp.where(ok, psd.at[gid].set(psd_val), psd)
            dmax = jnp.where(ok, dmax.at[gid].set(dmax_val), dmax)
            return values, psd, dmax

        if sequential:  # async mode: later blocks see earlier updates
            def run(values, psd, dmax, rows, slot_ok):
                def body(i, carry):
                    values, psd, dmax = carry
                    row = rows[i]
                    base, new, psd_val, dmax_val = process_iterated(
                        values, row, t_inner)
                    return write_one(values, psd, dmax, base, new, psd_val,
                                     dmax_val, gids[row], slot_ok[i])
                return lax.fori_loop(0, width, body, (values, psd, dmax))
        else:  # sync mode: all blocks read the same snapshot
            def run(values, psd, dmax, rows, slot_ok):
                bases, news, psd_vals, dmax_vals = jax.vmap(
                    lambda r: process_one(values, r))(rows)

                def body(i, carry):
                    values, psd, dmax = carry
                    return write_one(values, psd, dmax, bases[i], news[i],
                                     psd_vals[i], dmax_vals[i],
                                     gids[rows[i]], slot_ok[i])
                return lax.fori_loop(0, width, body, (values, psd, dmax))

        fn = jax.jit(run, donate_argnums=(0, 1, 2))
        self._fns[key] = fn
        return fn

    # -- host-side dispatch ---------------------------------------------------
    def _dispatch(self, values, psd, dmax, block_ids: np.ndarray,
                  sequential: bool):
        """Route global block ids to their storage group and run."""
        p, w = self.plan, self.config.width
        for store_key, cond in (("hot", block_ids < p.barrier_block),
                                ("cold", block_ids >= p.barrier_block)):
            ids = block_ids[cond]
            if ids.size == 0:
                continue
            offset = 0 if store_key == "hot" else p.barrier_block
            for at in range(0, ids.size, w):
                chunk = ids[at:at + w]
                rows = np.zeros(w, dtype=np.int32)
                ok = np.zeros(w, dtype=bool)
                rows[:chunk.size] = (chunk - offset).astype(np.int32)
                ok[:chunk.size] = True
                fn = self._get_fn(store_key, sequential)
                values, psd, dmax = fn(values, psd, dmax, jnp.asarray(rows),
                                       jnp.asarray(ok))
        return values, psd, dmax

    def _account(self, metrics: Metrics, ids: np.ndarray):
        p = self.plan
        for b in ids:
            lo, hi = p.block_range(int(b))
            metrics.updates += hi - lo
            metrics.block_loads += 1
            metrics.bytes_loaded += p.block_bytes(int(b))
            store = p.hot if b < p.barrier_block else p.cold
            row = int(b) if b < p.barrier_block else int(b) - p.barrier_block
            metrics.edges_processed += int(store.edges[row])

    # -- main loop ----------------------------------------------------------
    def run(self, max_iterations: int | None = None) -> RunResult:
        cfg, p = self.config, self.plan
        max_it = max_iterations or cfg.max_iterations
        mode = "barrier" if self.program.monotone_cooling else "universal"
        rep = RepartitionState.create(
            p.num_blocks, p.barrier_block, mode,
            interval=cfg.repartition_interval, growth=cfg.repartition_growth)
        # Per-block pruning floor: skipping blocks below t2/P is safe — if
        # every block were below it, SUM(psd) < t2 and we are converged.
        sched = Scheduler(width=cfg.width, i2=cfg.i2, cold_frac=cfg.cold_frac,
                          min_psd=cfg.t2 / max(p.num_blocks, 1))

        values = jnp.asarray(self.values0)
        psd = jnp.asarray(state_lib.init_psd(p.num_blocks))
        dmax = jnp.zeros(p.num_blocks, jnp.float32)
        psd_host = np.asarray(psd)
        metrics = Metrics()
        history = []

        with Timer() as t:
            it = 0
            while it < max_it:
                sel: Selection = sched.select(it, psd_host, rep.is_hot)
                if sel.hot_ids.size == 0 and sel.cold_ids.size == 0:
                    break
                values, psd, dmax = self._dispatch(
                    values, psd, dmax, sel.hot_ids, sequential=True)
                values, psd, dmax = self._dispatch(
                    values, psd, dmax, sel.cold_ids, sequential=False)
                processed = np.concatenate([sel.hot_ids, sel.cold_ids])
                self._account(metrics, processed)
                # staleness propagation (device-side max-product matvec):
                # a max per-vertex delta v in block j can move block b's
                # mean-PSD by at most decay * v * coupling(j->b).
                psd, dmax = self._post(psd, dmax)
                psd_host = np.asarray(psd)
                rep.maybe_repartition(it, psd_host, cfg.hot_ratio)
                history.append({
                    "iteration": it,
                    "psd_sum": float(psd_host[psd_host <
                                              state_lib.UNSEEN].sum()),
                    "unseen": int((psd_host >= state_lib.UNSEEN).sum()),
                    "hot_blocks": int(rep.is_hot.sum()),
                    "scheduled": int(processed.size),
                })
                it += 1
                if state_lib.converged(psd_host, cfg.t2):
                    metrics.converged = True
                    break
        metrics.iterations = it
        metrics.wall_time_s = t.elapsed
        out = np.asarray(values)[self.plan.inv]  # back to original ids
        return RunResult(values=out, metrics=metrics, history=history)


# -- Betweenness centrality (Brandes, sampled sources) -----------------------
def betweenness(graph: Graph, sources: list[int],
                config: EngineConfig = EngineConfig(),
                structure_aware: bool = True) -> tuple[np.ndarray, Metrics]:
    """BC per paper's algorithm set: the forward BFS waves run through the
    structure-aware engine (or the baseline when structure_aware=False); the
    path-counting and dependency accumulation are level-synchronous dense
    sweeps (they are single passes, not iterative-convergent phases)."""
    from repro.core import algorithms as algos
    from repro.core.baseline import BaselineEngine

    n = graph.n
    bc = np.zeros(n, dtype=np.float64)
    total = Metrics()
    s_arr, d_arr, _ = _coo(graph)
    for s in sources:
        prog = algos.bfs(source=s)
        eng = (StructureAwareEngine(graph, prog, config) if structure_aware
               else BaselineEngine(graph, prog, config))
        res = eng.run()
        dist = res.values
        for k, v in res.metrics.as_dict().items():
            if isinstance(v, (int, float)) and k != "converged":
                setattr(total, k, getattr(total, k) + v)
        # sigma: #shortest paths, level-synchronous accumulation
        finite = dist < algos.INF / 2
        max_lvl = int(dist[finite].max()) if finite.any() else 0
        sigma = np.zeros(n, dtype=np.float64)
        sigma[s] = 1.0
        on_sp = dist[d_arr] == dist[s_arr] + 1
        for lvl in range(1, max_lvl + 1):
            e = on_sp & (dist[d_arr] == lvl)
            np.add.at(sigma, d_arr[e], sigma[s_arr[e]])
        # delta: backward dependency accumulation
        delta = np.zeros(n, dtype=np.float64)
        for lvl in range(max_lvl, 0, -1):
            e = on_sp & (dist[d_arr] == lvl)
            contrib = sigma[s_arr[e]] / np.maximum(sigma[d_arr[e]], 1.0) * \
                (1.0 + delta[d_arr[e]])
            np.add.at(delta, s_arr[e], contrib)
        delta[s] = 0.0
        bc += delta
    return bc, total


def _coo(g: Graph):
    dst = np.repeat(np.arange(g.n, dtype=np.int64), g.in_deg)
    return g.in_src.astype(np.int64), dst, g.in_w
