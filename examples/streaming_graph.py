"""Streaming demo: a long-lived engine serving edge deltas beats rerunning
a batch job per snapshot.

A core-periphery graph (the paper's convergence-skew regime) converges
once, then a synthetic delta stream — preferential-attachment inserts,
random unfollows, the occasional celebrity burst — is ingested batch by
batch. Each batch re-heats only the dirty blocks and reconverges from the
previous fixpoint inside the already-compiled fused superstep; the cold
column reruns the full convergence from scratch on the same mutated graph.

With ``--resident-blocks`` the warm engine additionally runs OUT OF CORE:
only that many partition blocks keep their edge tiles on device, the rest
spill to a host/disk tier and page back in ahead of the schedule — the
values stay bitwise-identical to the fully resident run. ``--snapshot-dir``
then demos epoch persistence: save the live epoch, restore it in a fresh
engine, and warm-reconverge in a handful of supersteps instead of a cold
start.

    PYTHONPATH=src python examples/streaming_graph.py [--n 10000] \
        [--resident-blocks 8] [--snapshot-dir /tmp/epoch]
"""
import argparse
import dataclasses

import numpy as np

from repro.core import algorithms as A
from repro.core import graph as G
from repro.core.engine import EngineConfig
from repro.stream import StreamConfig, StreamingEngine, synthetic_stream


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=10000)
    ap.add_argument("--batches", type=int, default=5)
    ap.add_argument("--batch-size", type=int, default=150)
    ap.add_argument("--subblocks", type=int, default=1,
                    help="sub-blocks per partition block (hierarchical "
                         "activity tracking; 1 = flat blocks)")
    ap.add_argument("--resident-blocks", type=int, default=None,
                    help="device budget for the warm engine's edge tiles "
                         "(out-of-core; default: fully resident)")
    ap.add_argument("--spill-dir", default=None,
                    help="spill evicted tiles to npz segments here instead "
                         "of the host cache (needs --resident-blocks)")
    ap.add_argument("--snapshot-dir", default=None,
                    help="save the final epoch here, then restore + "
                         "warm-reconverge a fresh engine from it")
    args = ap.parse_args()

    g = G.core_periphery_graph(args.n, avg_deg=8, seed=1, chords=1,
                               weighted=True)
    cfg = EngineConfig(t2=1e-8, width=16, block_size=512,
                       subblocks=args.subblocks)
    prog = A.pagerank()

    warm_cfg = dataclasses.replace(cfg, resident_blocks=args.resident_blocks,
                                   spill_dir=args.spill_dir)
    warm = StreamingEngine(g, prog, warm_cfg)
    cold = StreamingEngine(g, prog, cfg, StreamConfig(warm=False))
    print(f"initial convergence: {warm.initial_result.metrics.iterations} "
          f"iterations, {warm.initial_result.metrics.edges_processed} edges")

    batches = synthetic_stream(g, args.batches, args.batch_size, seed=3,
                               delete_frac=0.2, weighted=True)
    print(f"\n{'batch':>5s} {'+ins':>5s} {'-del':>5s} {'dirty':>9s} "
          f"{'width':>6s} {'retired':>8s} "
          f"{'warm edges':>11s} {'cold edges':>11s} {'warm ms':>8s} "
          f"{'cold ms':>8s}")
    for i, b in enumerate(batches):
        rw = warm.ingest(b)
        rc = cold.ingest(b)
        # width/retired: the adaptive active set at work — a small batch
        # reconverges in a narrow dispatch bucket and ends with most
        # blocks individually retired, so effort shrinks with batch size
        print(f"{i:5d} {rw.inserts:5d} {rw.deletes:5d} "
              f"{rw.dirty_blocks:3d}/{rw.num_blocks:<3d}   "
              f"{rw.mean_dispatch_width:6.1f} "
              f"{rw.blocks_retired:3d}/{rw.num_blocks:<3d} "
              f"{rw.edges_processed:11d} {rc.edges_processed:11d} "
              f"{rw.latency_s * 1e3:8.1f} {rc.latency_s * 1e3:8.1f}")

    assert np.allclose(warm.values, cold.values, rtol=1e-3, atol=1e-5), \
        "warm and cold disagree!"
    mw, mc = warm.metrics, cold.metrics
    print(f"\nwarm vs cold over {mw.batches} batches: "
          f"{mc.edges_reprocessed / max(mw.edges_reprocessed, 1):.2f}x fewer "
          f"edges reprocessed, "
          f"{mc.latency_per_batch_s / max(mw.latency_per_batch_s, 1e-9):.2f}x "
          f"faster per batch, mean dirty fraction {mw.dirty_frac:.2f} "
          f"({mw.appended_blocks} in-place appends, {mw.rebuilt_blocks} "
          f"block rebuilds, {mw.plan_rebuilds} plan rebuilds); "
          f"mean dispatch width {mw.mean_dispatch_width:.1f} "
          f"of {warm.engine.config.width}, hot-depth histogram "
          f"{dict(sorted(mw.inner_depth_hist.items(), reverse=True))}")
    if args.subblocks > 1:
        print(f"hierarchical partitions (S={args.subblocks}): mean dirty "
              f"sub-block fraction {mw.subblock_dirty_frac:.2f} vs block "
              f"fraction {mw.dirty_frac:.2f}, mean sub-blocks swept per "
              f"block load {mw.mean_subblock_dispatch:.2f}")
    if args.resident_blocks is not None:
        P = warm.engine.plan.num_blocks
        init = warm.initial_result.metrics
        # paging never changes the schedule, so the budget run is
        # bitwise-equal to a fully resident warm engine (the property
        # tests in tests/test_ooc.py pin this); here the cold column
        # already cross-checks the converged values above
        print(f"out-of-core: {args.resident_blocks}/{P} blocks resident; "
              f"spill traffic incl. initial run: "
              f"{mw.spill_evictions + init.spill_evictions} evictions, "
              f"{(mw.bytes_spilled + init.bytes_spilled) / 1e6:.1f} MB out, "
              f"{(mw.bytes_fetched + init.bytes_fetched) / 1e6:.1f} MB in, "
              f"prefetch hit rate {mw.prefetch_hit_rate:.2f}")

    if args.snapshot_dir:
        warm.save_epoch(args.snapshot_dir).wait()
        back = StreamingEngine.restore(args.snapshot_dir, A.pagerank(),
                                       warm_cfg, verify=True)
        wm = back.initial_result.metrics
        assert np.allclose(back.values, warm.values, rtol=1e-4, atol=1e-6), \
            "restored epoch disagrees with the live engine!"
        print(f"\nepoch persistence: saved epoch {warm.epoch} to "
              f"{args.snapshot_dir}, restored + warm-reconverged in "
              f"{wm.iterations} supersteps (initial cold start took "
              f"{warm.initial_result.metrics.iterations})")


if __name__ == "__main__":
    main()
