"""StreamingEngine: ingest edge deltas, re-heat dirty blocks, reconverge.

Wraps one :class:`StructureAwareEngine` epoch and alternates

    ingest (incremental storage mutation, `apply.py`)
      -> dirty-block re-heat (affected blocks labelled hot with PSD =
         UNSEEN, convergence flags of clean blocks left converged,
         values warm-started from the previous fixpoint)
      -> fused convergence chunk (`engine._get_chunk`, the on-device
         while-loop — the steady-state path)

which is exactly the universal repartitioner's cold->hot path (§3.3)
driven by graph mutation instead of in-run decay. Because the engine's
edge state is a traced argument (`EdgeData`), the mutated tiles re-enter
the ALREADY-COMPILED superstep — no per-batch recompilation; a full plan
rebuild (and recompile) happens only when a block's slack tile run
overflows. The per-batch cost is proportional to the blocks the batch
TOUCHES, not to m: storage mutation is per-block (in-place slot kills,
watermark appends, per-block compactions), the device commit scatters
only the touched tile rows / changed aux entries / changed coupling rows
into donated resident buffers (`StructureAwareEngine.update_edge_rows`
and friends), and the delete-reset frontier closure is served from the
EdgeStore's by-src buckets instead of an O(m) CSR rebuild. The
`StreamBatchReport.upload_frac` column measures exactly this.

Delta-proportional reconvergence (adaptive engines): the warm restart
seeds the engine's block-local convergence counters so only the
perturbed blocks (dirty re-heats + aux bumps) start in the active set —
a 200-edit batch opens in a narrow dispatch-width bucket with a
cold-admission cadence scaled to the perturbed fraction
(`schedule.adaptive_i2`), and clean blocks re-enter only when the
staleness coupling lifts them over the pruning floor. Reconvergence
effort therefore scales with the batch, not the graph (BLADYG's
argument for delta-local recomputation). With hierarchical partitions
(`EngineConfig.subblocks > 1`) the arming is SUB-block granular: the
warm PSD/calm seeds mark only the sub-ranges holding the batch's touched
destination vertices, so a 10-edit batch whose endpoints pigeonhole into
10 different blocks still starts with ~10 armed sub-blocks — the engine
sweeps only those sub-ranges of each loaded block
(`StreamBatchReport.subblock_dirty_frac` / `mean_subblock_dispatch`
audit exactly this).

Non-monotone deletions: min/max programs can never take back a value, so
before the warm re-start the program's ``reset_on_delete`` hook
re-initialises every vertex whose value might (transitively) depend on a
deleted edge (KickStarter-style trimming; see `algorithms.py`). PageRank
needs no resets — its apply() ignores the old value, the warm state is
just a good initial guess.
"""
from __future__ import annotations

import dataclasses
import weakref

import numpy as np

from repro.analysis.contracts import decision_identical
from repro.core import state as state_lib
from repro.core.algorithms import VertexProgram, graph_successors
from repro.core.engine import (EdgeData, EngineConfig, RunResult,
                               StructureAwareEngine, WarmStart,
                               coupling_from_counts)
from repro.core.schedule import adaptive_i2
from repro.core.graph import Graph, edges_of, from_edges, symmetrize
from repro.core.metrics import StreamMetrics, Timer
from repro.obs import trace as obs_trace
from repro.stream.apply import EdgeStore, MutableTiledState
from repro.stream.delta import DeltaBatch


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    tile_slack: float = 0.5  # spare tile capacity fraction per block
    spare_tiles: int = 1  # flat extra tiles per block (covers empty blocks)
    warm: bool = True  # False: cold full recompute per batch (reference)


@dataclasses.dataclass
class StreamBatchReport:
    inserts: int
    deletes: int  # killed base edge copies (incl. parallel edges)
    dirty_blocks: int
    num_blocks: int
    appended_blocks: int
    killed_blocks: int  # blocks whose slots were invalidated in place
    rebuilt_blocks: int
    aux_bumped_blocks: int  # finite-PSD re-arms (aux change, not re-heated)
    plan_rebuild: bool
    vertices_reset: int
    iterations: int
    edges_processed: int
    bytes_uploaded: int  # actual host->device payload of this batch
    bytes_full: int  # what a full dynamic-state re-upload would cost
    ingest_time_s: float
    reconverge_time_s: float
    converged: bool
    # adaptive active-set stats of the warm reconvergence. All zero when
    # the batch needed no run; on the dense fallback retirement stays 0
    # but mean_dispatch_width reports the full configured width (the
    # fixed slate IS the dispatch width) and the depth histogram carries
    # the constant depth.
    blocks_retired: int = 0  # blocks retired at reconvergence end
    mean_dispatch_width: float = 0.0  # iteration-weighted bucket width
    inner_depth_hist: dict = dataclasses.field(default_factory=dict)
    # hierarchical-partition stats (degenerate at subblocks == 1: every
    # dirty block is one dirty sub-block and the mean dispatch is 1.0)
    subblocks: int = 1  # sub-blocks per block this epoch
    dirty_subblocks: int = 0  # armed sub-blocks (UNSEEN re-heats)
    block_loads: int = 0  # engine block loads of the reconvergence
    subblocks_retired: int = 0  # sub-blocks retired at reconvergence end
    mean_subblock_dispatch: float = 0.0  # live sub-blocks per block load
    # out-of-core residency traffic of the warm reconvergence (all zero
    # when the engine runs fully resident)
    spill_evictions: int = 0
    bytes_spilled: int = 0
    prefetch_hits: int = 0
    prefetch_misses: int = 0
    bytes_fetched: int = 0

    @property
    def dirty_frac(self) -> float:
        return self.dirty_blocks / max(self.num_blocks, 1)

    @property
    def subblock_dirty_frac(self) -> float:
        """Armed sub-blocks over sub-block slots — the granularity the
        P-pigeonhole can't see: a small batch arms few sub-blocks even
        when its endpoints land in most blocks."""
        return self.dirty_subblocks / max(self.num_blocks *
                                          self.subblocks, 1)

    @property
    def upload_frac(self) -> float:
        """Fraction of the full per-batch upload the batch actually paid —
        the tentpole number: it scales with the blocks a batch touches,
        not with m. A warm plan-rebuild batch pays exactly 1.0; cold
        reference mode never uploads warm values, so its rebuild batches
        land just under 1.0."""
        return self.bytes_uploaded / max(self.bytes_full, 1)

    @property
    def latency_s(self) -> float:
        return self.ingest_time_s + self.reconverge_time_s


@dataclasses.dataclass
class EpochState:
    """A consistent read view of one StreamingEngine epoch — what a query
    pins at admission (snapshot isolation for the serve subsystem).

    Host-side bookkeeping (coupling counts, degrees, per-block edge
    counts) is copied eagerly at snapshot time — O(P^2 + n), cheap. The
    device-resident edge state is NOT copied until an ingest is about to
    mutate it: :meth:`preserve` (called by the engine's ingest preamble
    for every live snapshot) takes the O(m) device copy exactly when the
    epoch would otherwise be lost to a donated commit, so pins on a quiet
    graph cost nothing and N pins of one epoch share one copy."""

    epoch: int
    engine: StructureAwareEngine  # geometry + compiled fns of the epoch
    coupling_counts: np.ndarray  # (P, P) block->block edge counts
    out_deg: np.ndarray  # (n,) permuted, incremental truth at pin time
    in_deg: np.ndarray
    edge_counts: np.ndarray  # (P,) per-block live edge counts
    _ed: EdgeData | None = None  # preserved copy; None -> engine's live state

    @property
    def ed(self) -> EdgeData:
        if self._ed is None:
            spill = self.engine.spill
            if spill is not None and spill.spilled_blocks.size:
                # safety net: never hand out a live view with spilled
                # holes — materialize a self-contained copy instead. The
                # eager paths (snapshot() under spill, the eviction hook,
                # the ingest preamble) normally preserve before this fires.
                self.preserve()
        return self._ed if self._ed is not None else self.engine.edge_state

    @property
    def preserved(self) -> bool:
        return self._ed is not None

    def preserve(self) -> None:
        if self._ed is None:
            self._ed = self.engine.edge_snapshot()


class StreamingEngine:
    """Long-lived engine over a mutating graph (fixed vertex set)."""

    def __init__(self, graph: Graph, program: VertexProgram,
                 config: EngineConfig = EngineConfig(),
                 stream: StreamConfig = StreamConfig()):
        self.program = program
        self.stream = stream
        self.config = dataclasses.replace(
            config, tile_slack=stream.tile_slack,
            spare_tiles=stream.spare_tiles, keep_dead_blocks=True)
        self.metrics = StreamMetrics()
        self.n = graph.n
        # epoch id: bumped once per ingest (and once per plan rebuild,
        # which happens inside an ingest) — the version a query pins
        self.epoch = 0
        self._snapshots: list = []  # weakrefs to unpreserved EpochStates
        s, d, w = edges_of(graph)
        self._build_epoch(s, d, w)
        # bootstrap: one cold run to the initial fixpoint
        self.initial_result: RunResult = self.engine.run()
        self._values = self.initial_result.values

    # -- epoch snapshots (serve-side snapshot isolation) ---------------------
    def snapshot(self) -> EpochState:
        """Pin the current epoch. The returned view stays consistent
        across future :meth:`ingest` calls (the ingest preamble preserves
        the device state of every live pin before mutating it); it is
        tracked by weakref, so dropping the last reference makes future
        ingests free again."""
        with obs_trace.span("snapshot", cat="stream", epoch=self.epoch):
            es = EpochState(
                epoch=self.epoch, engine=self.engine,
                coupling_counts=self.W.copy(),
                out_deg=self.out_deg.copy(), in_deg=self.in_deg.copy(),
                edge_counts=np.array(self.engine.edge_counts))
            spill = self.engine.spill
            if spill is not None and spill.spilled_blocks.size:
                # under an out-of-core budget the live edge state already
                # has spilled holes: preserve now (edge_snapshot
                # materializes the holes from the spill tier), instead of
                # lazily at the next ingest — the pin must be readable
                # before then
                es.preserve()
                self.metrics.snapshots_preserved += 1
            self._snapshots.append(weakref.ref(es))
        return es

    def _preserve_pinned(self) -> int:
        """Device-copy every live, not-yet-preserved epoch snapshot — the
        ingest preamble, run before any commit can donate the pinned
        buffers. Pins of the same epoch SHARE one copy (they are read-only
        views of identical state), so N pins cost one O(m) copy. After
        this every tracked pin is self-contained and the tracking list
        resets. Returns the number of copies taken."""
        copies = 0
        shared: dict[int, EdgeData] = {}
        for ref in self._snapshots:
            es = ref()
            if es is None or es.preserved:
                continue
            ed = shared.get(es.epoch)
            if ed is None:
                es.preserve()
                shared[es.epoch] = es.ed
                copies += 1
            else:
                es._ed = ed
        self._snapshots = []
        return copies

    def _on_spill_evict(self) -> None:
        """Spill-tier pre-eviction hook: pinned epochs must survive the
        eviction of their blocks. The eviction scatter really invalidates
        the device rows, so every live pin is preserved first —
        ``edge_snapshot`` materializes any already-spilled holes from the
        tier's truth, and the about-to-be-evicted rows are still resident
        at hook time."""
        self.metrics.snapshots_preserved += self._preserve_pinned()

    # -- epoch management ----------------------------------------------------
    def _build_epoch(self, src: np.ndarray, dst: np.ndarray,
                     w: np.ndarray) -> None:
        """(Re)build engine + mutable mirrors from a base COO snapshot."""
        g = from_edges(self.n, src, dst, w)
        self.engine = StructureAwareEngine(g, self.program, self.config)
        plan = self.engine.plan
        inv = plan.inv
        sym = self.program.needs_symmetric
        self.store = EdgeStore(inv[src], inv[dst],
                               np.asarray(w, dtype=np.float32), self.n,
                               plan.num_blocks, plan.block_size, sym)
        self.tiles = MutableTiledState(plan.unified)
        # incrementally-maintained degrees of the INTERNAL (symmetrized)
        # graph, permuted order — the activity inputs (paper Eq. 1)
        self.out_deg = plan.graph.out_deg.astype(np.int64)
        self.in_deg = plan.graph.in_deg.astype(np.int64)
        # block -> block internal edge counts (staleness coupling truth)
        self.W = self.engine.coupling_counts.copy()
        self._aux = np.array(self.engine.aux)
        # every registered init carries @structure_independent
        # (repro.analysis.contracts — the normative statement), so one
        # epoch snapshot serves every delete-reset without rebuilding a
        # Graph
        self._init_values = np.asarray(self.program.init(g)[0])
        self._prewarm_scatters()
        # compile every dispatch-width bucket at epoch build: a warm batch
        # lands straight in a narrow bucket, and paying that compile inside
        # a batch's reconverge latency would bill one batch for all
        self.engine.prewarm_buckets()
        spill = self.engine.spill
        if spill is not None:
            # the host tile mirror is the truth under streaming mutation:
            # evictions never need a device readback and fetches re-scatter
            # CURRENT truth even for blocks mutated while spilled (an
            # ingest commit to a non-resident block is harmless — the
            # fetch overwrites its rows wholesale)
            spill.row_source = self.tiles.rows2d
            spill.on_evict = self._on_spill_evict

    def _prewarm_scatters(self) -> None:
        """Compile the chunked device-scatter executables at epoch build
        (identity writes of row/entry 0) so a long-lived engine never pays
        the compile inside a batch's ingest latency."""
        eng = self.engine
        z = np.zeros(1, dtype=np.int64)
        eng.update_edge_rows(z, **self.tiles.rows2d(z))
        eng.update_aux(z, self._aux[:1])
        eng.update_coupling_rows(
            z, coupling_from_counts(self.W[:1], self.program,
                                    eng.plan.block_size))

    def _rebuild_epoch(self) -> None:
        ps, pd, w = self.store.live_base()
        order = self.engine.plan.order
        self._build_epoch(order[ps], order[pd], w)
        self.metrics.plan_rebuilds += 1

    # -- public state --------------------------------------------------------
    @property
    def values(self) -> np.ndarray:
        """Current converged values, indexed by original vertex id."""
        return self._values

    def current_graph(self) -> Graph:
        """The mutated base graph (original ids) — what a cold run sees."""
        ps, pd, w = self.store.live_base()
        order = self.engine.plan.order
        return from_edges(self.n, order[ps], order[pd], w)

    def activity(self, alpha: float | None = None) -> np.ndarray:
        """Incrementally-maintained per-vertex activity a*in + b*out (the
        degree function D(v) = out + alpha*in of paper Eq. 1), original
        ids — no rescan of the edge set."""
        a = self.engine.plan.alpha if alpha is None else alpha
        d = (self.out_deg + a * self.in_deg)
        return d[self.engine.plan.inv]

    # -- epoch persistence (warm restarts; repro.ooc.snapshot) ---------------
    def save_epoch(self, ckpt, step: int | None = None):
        """Persist the current epoch (edge truth + fixpoint values +
        activity state) through a :class:`repro.ooc.snapshot
        .GraphCheckpoint`. ``ckpt`` is a directory path or an existing
        GraphCheckpoint; ``step`` defaults to the epoch counter. Every
        inter-batch state is a fixpoint (ingest ends with reconvergence),
        so the snapshot is consistent by construction. Returns the
        checkpoint (call ``.wait()`` to block on the async writer)."""
        from repro.ooc.snapshot import GraphCheckpoint
        if not isinstance(ckpt, GraphCheckpoint):
            ckpt = GraphCheckpoint(ckpt)
        ckpt.save(self, step)
        return ckpt

    @classmethod
    def restore(cls, ckpt, program: VertexProgram,
                config: EngineConfig = EngineConfig(),
                stream: StreamConfig = StreamConfig(),
                step: int | None = None, verify: bool = True):
        """Warm-restart a StreamingEngine from a saved epoch. The epoch
        geometry is rebuilt deterministically from the checkpointed COO
        (``build_plan``'s activity sort is a pure function of the edge set
        and config — the same path every overflow batch takes), and the
        engine warm-starts from the checkpointed fixpoint values instead
        of ``program.init``. With ``verify`` (default) a verification
        pass re-heats every block once (PSD = UNSEEN, universal mode) and
        reconverges — from a fixpoint the deltas die immediately, which
        is the measured warm-vs-cold restart win (``initial_result``
        carries its metrics); ``verify=False`` trusts the checkpoint and
        skips the run. A checkpoint written under one residency budget
        restores under any other (``config.resident_blocks`` applies to
        the NEW engine)."""
        from repro.ooc.snapshot import GraphCheckpoint
        if not isinstance(ckpt, GraphCheckpoint):
            ckpt = GraphCheckpoint(ckpt)
        tree, meta = ckpt.load(step)
        src, dst, w = tree["edges"]
        self = cls.__new__(cls)
        self.program = program
        self.stream = stream
        self.config = dataclasses.replace(
            config, tile_slack=stream.tile_slack,
            spare_tiles=stream.spare_tiles, keep_dead_blocks=True)
        self.metrics = StreamMetrics()
        self.n = int(meta["n"])
        self.epoch = int(meta["epoch"])
        self._snapshots = []
        self._build_epoch(np.asarray(src, dtype=np.int64),
                          np.asarray(dst, dtype=np.int64),
                          np.asarray(w, dtype=np.float32))
        self._values = np.asarray(tree["values"])
        self.initial_result = None
        self.restored_meta = meta
        if verify:
            plan = self.engine.plan
            vals = self._values[plan.order].astype(np.float32)
            res = self.engine.run(warm=WarmStart(
                values=self.engine.pad_values(vals),
                psd=state_lib.init_psd(plan.num_blocks,
                                       self.config.subblocks),
                is_hot=np.ones(plan.num_blocks, dtype=bool)))
            self._values = res.values
            self.initial_result = res
        return self

    # -- ingest --------------------------------------------------------------
    def ingest(self, batch: DeltaBatch) -> StreamBatchReport:
        with obs_trace.span("ingest", cat="stream",
                            inserts=batch.n_inserts,
                            deletes=batch.n_deletes,
                            epoch=self.epoch) as sp:
            report = self._ingest_impl(batch)
            sp.set(dirty_blocks=report.dirty_blocks,
                   plan_rebuild=report.plan_rebuild,
                   iterations=report.iterations)
        return report

    def _ingest_impl(self, batch: DeltaBatch) -> StreamBatchReport:
        prog, eng = self.program, self.engine
        plan = eng.plan
        c = plan.block_size
        inv = plan.inv
        self._validate(batch)
        # snapshot isolation: queries pinned to the current epoch keep
        # reading it — copy their device state before this batch's donated
        # commits (or plan rebuild) can touch it
        self.metrics.snapshots_preserved += self._preserve_pinned()
        sym = prog.needs_symmetric
        appended = rebuilt = killed_blocks = 0
        n_reset = 0
        bytes_up = 0
        empty = np.empty(0, dtype=np.int64)
        reset_blocks = empty
        reset_verts = empty  # permuted ids, for sub-block-granular arming

        with Timer() as t_ing:
            # 1. mutate the base truth (deletes first, then inserts)
            killed = self.store.kill_pairs(inv[batch.del_src],
                                           inv[batch.del_dst])
            kps, kpd = self.store.psrc[killed], self.store.pdst[killed]
            killed_orig = (plan.order[kps], plan.order[kpd],
                           self.store.w[killed].copy())
            ip_src, ip_dst = inv[batch.ins_src], inv[batch.ins_dst]
            ins_ids = self.store.insert(ip_src, ip_dst, batch.ins_w)
            iw = self.store.w[ins_ids]
            self._bump(killed, -1)
            self._bump(ins_ids, +1)
            # coupling rows whose counts moved (refresh is O(rows * P))
            wrow_parts = [kps // c, ip_src // c]
            if sym:
                wrow_parts += [kpd // c, ip_dst // c]
            wrows = np.unique(np.concatenate(wrow_parts))

            # 2. per-block tile mutation. Deletes: in-place slot kills
            # (masked holes — only the rows holding killed slots move);
            # symmetric engines rebuild the touched blocks from truth
            # instead, since a mirror slot of (s, d) is sig-identical to a
            # base slot of (d, s). Inserts: append at the watermark, with
            # a rebuild (= hole compaction, the store already holds this
            # batch's inserts) when the watermark hits capacity.
            overflow = False
            rebuild_set = empty
            kill_set = empty
            if killed.size:
                if sym:
                    rebuild_set = np.union1d(self._blocks_of(kpd),
                                             self._blocks_of(kps))
                    for b in rebuild_set:
                        if not self.tiles.rebuild(
                                int(b), *self.store.gather_block(int(b))):
                            overflow = True
                            break
                        rebuilt += 1
                else:
                    kb = kpd // c
                    kill_set = np.unique(kb)
                    for b in kill_set:
                        sel = kb == b
                        self.tiles.kill(int(b), kps[sel],
                                        kpd[sel] - int(b) * c)
                    killed_blocks = int(kill_set.size)
            ins_rows = [(ip_dst // c, ip_src, ip_dst, iw)]
            if sym:
                ins_rows.append((ip_src // c, ip_dst, ip_src, iw))
            append_set = np.setdiff1d(
                np.unique(np.concatenate([blk for blk, *_ in ins_rows]))
                if ins_ids.size else empty, rebuild_set)
            compacted: list[int] = []
            if not overflow:
                for b in append_set:
                    asrc = np.concatenate(
                        [es[blk == b] for blk, es, _, _ in ins_rows])
                    adst = np.concatenate(
                        [ed[blk == b] for blk, _, ed, _ in ins_rows])
                    aw = np.concatenate(
                        [ew[blk == b] for blk, _, _, ew in ins_rows])
                    if self.tiles.append(
                            int(b), asrc.astype(np.int32),
                            (adst - int(b) * c).astype(np.int32), aw):
                        appended += 1
                    elif self.tiles.rebuild(
                            int(b), *self.store.gather_block(int(b))):
                        rebuilt += 1  # watermark full, holes reclaimed
                        compacted.append(int(b))
                    else:
                        overflow = True
                        break
            if compacted and kill_set.size:
                # a kill-touched block whose append fell back to a rebuild
                # is a rebuild, not in-place maintenance — count it once
                kill_set = np.setdiff1d(
                    kill_set, np.asarray(compacted, dtype=np.int64))
                killed_blocks = int(kill_set.size)

            # 3. non-monotone deletions: KickStarter-style trimming before
            # the warm start (min/max programs cannot take a value back).
            # The frontier closure is served straight from the EdgeStore's
            # by-src buckets — no O(m) CSR rebuild per delete batch; the
            # Graph-building hook remains only as a fallback for programs
            # that predate the oracle interface. Cold reference mode
            # restarts from program.init, so it skips trimming entirely.
            if self.stream.warm and killed.size:
                if prog.reset_on_delete_frontier is not None:
                    mask = np.asarray(prog.reset_on_delete_frontier(
                        self._successors, self.n, self._values,
                        *killed_orig))
                elif prog.reset_on_delete is not None:
                    mask = np.asarray(prog.reset_on_delete(
                        self._internal_graph(), self._values, *killed_orig))
                else:
                    mask = None
                if mask is not None and mask.any():
                    self._values = self._values.copy()
                    self._values[mask] = self._init_values[mask]
                    reset_verts = inv[np.flatnonzero(mask)]
                    reset_blocks = self._blocks_of(reset_verts)
                    n_reset = int(mask.sum())

            # 4. aux refresh from the incremental degrees — batched to the
            # batch's own endpoints (registered aux_fns carry @elementwise
            # — repro.analysis.contracts — so only vertices whose degrees
            # moved can change), never an O(n) rescan. A changed SOURCE aux silently changes the aggregates
            # of its out-neighbour blocks; programs exposing aux_delta turn
            # that into a finite PSD bump (scheduled by priority, skipped
            # below the pruning floor) instead of an UNSEEN re-heat of
            # nearly every block.
            aux_dirty = empty
            aux_dirty_sub = None  # (blk, sub) index pair at S > 1
            aux_bump = None  # (P,) flat / (P, S) sub-resolved
            aux_changed = empty
            aux_vals = np.empty(0, dtype=np.float32)
            subblocks = eng.config.subblocks
            if prog.aux_fn is not None and not overflow and (
                    killed.size or ins_ids.size):
                cand = np.unique(np.concatenate(
                    [kps, kpd, ip_src, ip_dst]))
                a_new = np.asarray(prog.aux_fn(self.out_deg[cand],
                                               self.in_deg[cand]),
                                   dtype=np.float32)
                ch = a_new != self._aux[cand]
                aux_changed, aux_vals = cand[ch], a_new[ch]
                if aux_changed.size:
                    if prog.aux_delta is not None and prog.combine == "sum":
                        dmsg = np.asarray(prog.aux_delta(
                            self._values[plan.order[aux_changed]],
                            self._aux[aux_changed], aux_vals))
                        mass = self.store.out_block_mass(
                            aux_changed, dmsg, subblocks)
                        # sound per-block bound: damping * (message-delta
                        # mass entering the block) / C, the same form the
                        # staleness coupling uses; at S > 1 the mass is
                        # resolved per destination sub-range, so only the
                        # sub-blocks actually fed by the changed sources
                        # re-arm (block-granular bumps would re-open the
                        # pigeonhole: ~every bump arms S sub-blocks)
                        aux_bump = (prog.damping * mass / c).astype(
                            np.float32)
                    else:
                        # min/max programs: UNSEEN re-heat of the changed
                        # sources' out-neighbourhood, resolved to the
                        # destination sub-ranges when S > 1
                        _, sdst, _ = self.store.successors(aux_changed)
                        aux_dirty = np.unique(sdst // c)
                        if subblocks > 1:
                            ks_ = c // subblocks
                            aux_dirty_sub = (sdst // c, (sdst % c) // ks_)
                    self._aux[aux_changed] = aux_vals

            # 5. commit to the engine — inside the ingest timer, so both
            # the worst case (overflow -> full plan rebuild) and the
            # device upload are billed to the batch's latency
            calm0 = None
            i2_warm = None
            subblocks = eng.config.subblocks
            if overflow:
                # a block outgrew its slack capacity: new epoch
                # (re-permute by current activity, re-provision slack,
                # recompile); values stay warm, every block re-heats. The
                # partial appends/rebuilds made before the overflow were
                # discarded with the old tiles — do not let them count as
                # in-place maintenance. Everything is perturbed, so the
                # warm run starts fully active (no calm seed, base i2).
                appended = rebuilt = killed_blocks = 0
                self._rebuild_epoch()
                eng = self.engine
                plan = eng.plan
                dirty = np.ones(plan.num_blocks, dtype=bool)
                dirty_sub = np.ones((plan.num_blocks, subblocks),
                                    dtype=bool)
                is_hot = np.zeros(plan.num_blocks, dtype=bool)
                is_hot[:plan.barrier_block] = True
                psd0 = state_lib.init_psd(plan.num_blocks, subblocks)
                # the warm-values upload is billed where it happens (below)
                bytes_up = eng.full_upload_bytes() - eng.values_nbytes
            else:
                # device-side incremental commit: scatter only the touched
                # tile rows / changed aux entries / changed coupling rows
                # into the resident (donated) buffers — O(touched), not
                # O(m), host->device traffic
                rows = self.tiles.pop_dirty_rows()
                if rows.size:
                    bytes_up += eng.update_edge_rows(
                        rows, **self.tiles.rows2d(rows))
                bytes_up += eng.update_aux(aux_changed, aux_vals)
                if wrows.size:
                    bytes_up += eng.update_coupling_rows(
                        wrows, coupling_from_counts(self.W[wrows], prog, c))
                eng.edge_counts = self.tiles.live.copy()
                dirty = np.zeros(plan.num_blocks, dtype=bool)
                for ids in (kill_set, rebuild_set, append_set, aux_dirty,
                            reset_blocks):
                    dirty[ids.astype(np.int64)] = True
                # sub-block refinement of the dirty set: arm only the
                # sub-ranges holding this batch's touched DESTINATION
                # vertices (mirror dsts too on symmetric engines) and the
                # delete-reset frontier — the dst vertex is where an edge
                # mutation changes an aggregate. Aux-dirty re-heats are
                # likewise resolved to the destination sub-ranges the
                # changed sources actually feed (whole rows at S = 1).
                # Block-level `dirty` stays the truth for reports/is_hot/
                # i2 — at S = 1 the two views coincide column for column.
                ksub = c // subblocks
                dirty_sub = np.zeros((plan.num_blocks, subblocks),
                                     dtype=bool)
                tv_parts = [kpd, ip_dst, reset_verts]
                if sym:
                    tv_parts += [kps, ip_src]
                tv = np.concatenate([np.asarray(v, dtype=np.int64)
                                     for v in tv_parts])
                if tv.size:
                    dirty_sub[tv // c, (tv % c) // ksub] = True
                if aux_dirty_sub is not None:
                    dirty_sub[aux_dirty_sub] = True
                else:
                    dirty_sub[aux_dirty.astype(np.int64)] = True
                # safety net: a dirty block must own >= 1 armed sub-block
                # (rebuild bookkeeping paths all arm through tv/aux, but
                # the invariant is load-bearing for convergence)
                dirty_sub |= (dirty & ~dirty_sub.any(axis=1))[:, None]
                dirty_sub &= dirty[:, None]
                is_hot = dirty.copy()
                # block-level view of the (possibly sub-resolved) aux bump:
                # a block is bumped iff any of its sub-blocks is
                bump_blk = (None if aux_bump is None else
                            aux_bump.max(axis=-1) if aux_bump.ndim == 2
                            else aux_bump)
                if bump_blk is not None:
                    # bumped blocks are scheduled with hot priority (their
                    # pending delta is known and front-loading it converges
                    # in fewer sweeps) but stay out of the dirty set: they
                    # carry a finite prunable PSD, not the UNSEEN re-heat
                    is_hot |= bump_blk > 0
                psd0 = state_lib.warm_psd_sub(plan.num_blocks, subblocks,
                                              dirty_sub, aux_bump)
                if eng.config.adaptive:
                    # delta-proportional warm restart: only the perturbed
                    # sub-blocks (dirty re-heats + aux bumps) start active,
                    # so the reconvergence opens in a dispatch bucket sized
                    # to the batch, with a cold-admission cadence scaled to
                    # the perturbed fraction — effort follows the delta,
                    # not the graph. A 10-edit batch arms ~10 sub-blocks
                    # even when its endpoints pigeonhole into 10 blocks.
                    armed = dirty.copy()
                    armed_sub = dirty_sub.copy()
                    if aux_bump is not None:
                        armed |= bump_blk > 0
                        armed_sub |= (aux_bump > 0 if aux_bump.ndim == 2
                                      else (aux_bump > 0)[:, None])
                    calm0 = state_lib.warm_calm_sub(
                        plan.num_blocks, subblocks, armed_sub,
                        eng.config.retire_after)
                    i2_warm = adaptive_i2(eng.config.i2, plan.num_blocks,
                                          int(armed.sum()))

            # 6. reclaim dead store rows — at the very END of ingest, after
            # every use of this batch's edge ids (compaction renumbers
            # rows, invalidating killed/ins_ids and anything derived)
            self.store.maybe_compact()

        res = None
        with obs_trace.span("reconverge", cat="stream",
                            warm=self.stream.warm), Timer() as t_run:
            if self.stream.warm:
                if psd0.any():
                    vals_perm = self._values[self.engine.plan.order].astype(
                        np.float32)
                    res = self.engine.run(warm=WarmStart(
                        values=self.engine.pad_values(vals_perm),
                        psd=psd0, is_hot=is_hot, calm=calm0, i2=i2_warm))
                    bytes_up += self.engine.values_nbytes
            else:
                # reference mode: cold full recompute on the SAME mutated
                # storage (sound because inits are @structure_independent)
                res = self.engine.run()
            if res is not None:
                self._values = res.values
        self.epoch += 1  # the mutated graph is the next epoch

        n_bumped = (int(((bump_blk > 0) & ~dirty).sum())
                    if aux_bump is not None else 0)
        report = StreamBatchReport(
            inserts=batch.n_inserts, deletes=int(killed.size),
            dirty_blocks=int(dirty.sum()),
            num_blocks=int(self.engine.plan.num_blocks),
            appended_blocks=appended, killed_blocks=killed_blocks,
            rebuilt_blocks=rebuilt, aux_bumped_blocks=n_bumped,
            plan_rebuild=bool(overflow), vertices_reset=n_reset,
            iterations=res.metrics.iterations if res else 0,
            edges_processed=res.metrics.edges_processed if res else 0,
            bytes_uploaded=int(bytes_up),
            bytes_full=int(self.engine.full_upload_bytes()),
            ingest_time_s=t_ing.elapsed, reconverge_time_s=t_run.elapsed,
            converged=res.metrics.converged if res else True,
            blocks_retired=res.metrics.blocks_retired if res else 0,
            mean_dispatch_width=(res.metrics.mean_dispatch_width
                                 if res else 0.0),
            inner_depth_hist=dict(res.metrics.inner_depth_hist)
            if res else {},
            subblocks=subblocks,
            dirty_subblocks=int(dirty_sub.sum()),
            block_loads=res.metrics.block_loads if res else 0,
            subblocks_retired=res.metrics.subblocks_retired if res else 0,
            mean_subblock_dispatch=(res.metrics.mean_subblock_dispatch
                                    if res else 0.0),
            spill_evictions=res.metrics.spill_evictions if res else 0,
            bytes_spilled=res.metrics.bytes_spilled if res else 0,
            prefetch_hits=res.metrics.prefetch_hits if res else 0,
            prefetch_misses=res.metrics.prefetch_misses if res else 0,
            bytes_fetched=res.metrics.bytes_fetched if res else 0)
        self._absorb(report)
        return report

    # -- internals -----------------------------------------------------------
    def _validate(self, batch: DeltaBatch) -> None:
        for a in (batch.ins_src, batch.ins_dst, batch.del_src,
                  batch.del_dst):
            if a.size and (a.min() < 0 or a.max() >= self.n):
                raise ValueError(
                    f"delta vertex ids must be in [0, {self.n}) — the "
                    "streaming engine mutates edges over a fixed vertex set")

    def _blocks_of(self, vertices: np.ndarray) -> np.ndarray:
        if vertices.size == 0:
            return np.empty(0, dtype=np.int64)
        return np.unique(vertices // self.engine.plan.block_size)

    def _bump(self, ids: np.ndarray, sign: int) -> None:
        """Degree + block-coupling counts for internal copies (with mirrors
        for symmetric engines) — incremental, no edge rescans. At S > 1
        the coupling counts carry a destination-sub axis (P, P, S); the
        sub index is (dst % C) // sub_size, free from the ids in hand."""
        if ids.size == 0:
            return
        plan = self.engine.plan
        c = plan.block_size
        ks = plan.sub_size
        ps, pd = self.store.psrc[ids], self.store.pdst[ids]
        np.add.at(self.out_deg, ps, sign)
        np.add.at(self.in_deg, pd, sign)
        if self.W.ndim == 2:
            np.add.at(self.W, (ps // c, pd // c), sign)
        else:
            np.add.at(self.W, (ps // c, pd // c, (pd % c) // ks), sign)
        if self.program.needs_symmetric:
            np.add.at(self.out_deg, pd, sign)
            np.add.at(self.in_deg, ps, sign)
            if self.W.ndim == 2:
                np.add.at(self.W, (pd // c, ps // c), sign)
            else:
                np.add.at(self.W, (pd // c, ps // c, (ps % c) // ks), sign)

    def _internal_graph(self) -> Graph:
        g = self.current_graph()
        return symmetrize(g) if self.program.needs_symmetric else g

    @decision_identical(twin=graph_successors)
    def _successors(self, frontier: np.ndarray) -> tuple[np.ndarray,
                                                         np.ndarray,
                                                         np.ndarray]:
        """Out-edge oracle over ORIGINAL vertex ids for the delete-reset
        frontier closure, served from the EdgeStore's by-src buckets —
        replaces the per-delete-batch ``from_edges`` CSR rebuild. Must
        return the same (src, dst, w) multiset as
        :func:`repro.core.algorithms.graph_successors` over the built
        graph (the @decision_identical twin; enforced by the stream
        equivalence suite)."""
        plan = self.engine.plan
        ps, pd, w = self.store.successors(plan.inv[frontier])
        return plan.order[ps], plan.order[pd], w

    def _absorb(self, r: StreamBatchReport) -> None:
        m = self.metrics
        m.batches += 1
        m.ingest_time_s += r.ingest_time_s
        m.reconverge_time_s += r.reconverge_time_s
        m.edges_inserted += r.inserts
        m.edges_deleted += r.deletes
        m.edges_reprocessed += r.edges_processed
        m.iterations += r.iterations
        if not r.plan_rebuild:
            # dirty_frac measures the in-place re-heat only: an overflow
            # batch re-heats everything by construction and is tracked by
            # plan_rebuilds instead of skewing the average
            m.dirty_blocks += r.dirty_blocks
            m.blocks_seen += r.num_blocks
            m.dirty_subblocks += r.dirty_subblocks
            m.subblocks_seen += r.num_blocks * r.subblocks
        m.appended_blocks += r.appended_blocks
        m.killed_blocks += r.killed_blocks
        m.rebuilt_blocks += r.rebuilt_blocks
        m.aux_bumped_blocks += r.aux_bumped_blocks
        m.vertices_reset += r.vertices_reset
        m.bytes_uploaded += r.bytes_uploaded
        m.bytes_full += r.bytes_full
        m.blocks_retired += r.blocks_retired
        m.width_iterations += r.mean_dispatch_width * r.iterations
        m.subblocks_retired += r.subblocks_retired
        # mean_subblock_dispatch is block-load-weighted: recover the exact
        # live-sub-block count from the per-run mean (the division by
        # block_loads round-trips within an ulp; round() restores the int)
        m.subblock_loads += int(round(r.mean_subblock_dispatch *
                                      r.block_loads))
        m.subblock_load_slots += r.block_loads
        m.spill_evictions += r.spill_evictions
        m.bytes_spilled += r.bytes_spilled
        m.prefetch_hits += r.prefetch_hits
        m.prefetch_misses += r.prefetch_misses
        m.bytes_fetched += r.bytes_fetched
        for d, cnt in r.inner_depth_hist.items():
            m.inner_depth_hist[d] = m.inner_depth_hist.get(d, 0) + cnt
