"""AdamW (decoupled weight decay) + cosine LR, pure JAX.

Optimizer state is a pytree mirroring params (m, v in f32). ZeRO-1 sharding
is applied at the launch layer by giving m/v the same PartitionSpecs as the
params plus an extra shard over the data axis where divisible (see
launch/sharding.py)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    min_lr: float = 3e-5
    warmup_steps: int = 100
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def cosine_lr(cfg: AdamWConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = cfg.peak_lr * step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr + 0.5 * (cfg.peak_lr - cfg.min_lr) * \
        (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, state, params, cfg: AdamWConfig):
    step = state["step"] + 1
    lr = cosine_lr(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        pf = p.astype(jnp.float32)
        pf = pf - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                        + cfg.weight_decay * pf)
        return pf.astype(p.dtype), m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"lr": lr, "grad_norm": gnorm}
