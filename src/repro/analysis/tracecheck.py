"""Trace-time contract enforcement + golden jaxpr hashing.

Drives the registered contracts (:mod:`repro.analysis.contracts`)
through jax's abstract evaluation:

  * ``elementwise`` — trace on ``ShapeDtypeStruct``s and reject jaxprs
    containing cross-axis-0 primitives (gather/scatter/sort/reduce/
    scan); numpy host functions that cannot trace fall back to a
    concrete slicewise probe (``f(x)[i] == f(x[i:i+1])[0]``).
  * ``structure_independent`` — differential check: init values over two
    same-``n`` graphs with different edge sets must be bitwise equal
    (``lane_init`` sees no graph at all; it is probed for determinism).
  * ``decision_identical`` — seeded trials comparing the device select
    against its host twin, decision for decision.
  * ``one_executable_per`` — build a tiny engine, call each compiled-
    function getter twice per key, assert the identical object comes
    back and the cache does not grow.
  * golden jaxprs — canonicalized-and-hashed traces of the compiled
    entry points (device select, tiled sweeps, fused chunk, lane chunk,
    row scatter), committed in ``golden_jaxprs.json`` so a silent trace-
    structure change diffs loudly in CI. Hashes are stable for a fixed
    jax version; on a version mismatch the comparison is SKIPPED (with a
    regeneration hint), not failed.
"""
from __future__ import annotations

import hashlib
import inspect
import json
import re
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.contracts import Contract
from repro.analysis.lint import Finding

GOLDEN_PATH = Path(__file__).with_name("golden_jaxprs.json")

# Primitives an elementwise (axis-0-local) function must not emit.
# Structural data movement, reductions, sorts, scans and inner control
# flow all couple vertices; pure elementwise math never lowers to these.
_CROSS_VERTEX_PRIMITIVES = {
    "gather", "scatter", "scatter-add", "scatter-mul", "scatter-min",
    "scatter-max", "dynamic_slice", "dynamic_update_slice", "sort",
    "cumsum", "cumprod", "cummax", "cummin", "cumlogsumexp",
    "reduce_sum", "reduce_prod", "reduce_max", "reduce_min",
    "reduce_and", "reduce_or", "reduce_xor", "argmax", "argmin",
    "reduce_precision_window", "reduce_window_sum", "reduce_window_max",
    "dot_general", "conv_general_dilated", "while", "scan", "cond",
    "segment_sum",
}


# -- elementwise -------------------------------------------------------------
def _probe_args(contract: Contract, n: int, rng: np.random.Generator):
    """Concrete seeded inputs for a contract target: one array per
    parameter (axis 0 of length ``n``), honoring an explicit ``shapes``
    spec ("static" entries become plain Python scalars)."""
    params = list(inspect.signature(contract.target).parameters)
    shapes = contract.meta.get("shapes")
    args = []
    for i, name in enumerate(params):
        spec = shapes[i] if shapes is not None and i < len(shapes) else (n,)
        if spec == "static":
            args.append(n)
            continue
        shape = tuple(n if d == 8 and j == 0 else d
                      for j, d in enumerate(spec))
        # positive, non-degenerate values: aux_fn divides by these, and
        # min-combine deltas need distinct magnitudes
        args.append((rng.random(shape) * 4.0 + 0.5).astype(np.float32))
    return args


def _walk_jaxpr(jaxpr) -> set[str]:
    prims: set[str] = set()
    for eqn in jaxpr.eqns:
        prims.add(eqn.primitive.name)
        for v in eqn.params.values():
            inner = getattr(v, "jaxpr", None)
            if inner is not None:
                prims |= _walk_jaxpr(inner)
            elif hasattr(v, "eqns"):
                prims |= _walk_jaxpr(v)
    return prims


def check_elementwise(contract: Contract) -> list[Finding]:
    rng = np.random.default_rng(7)
    n = 8
    args = _probe_args(contract, n, rng)
    where = f"{contract.module}:{contract.qualname}"
    # jaxpr path: traceable (jnp) functions get the primitive denylist
    try:
        jaxpr = jax.make_jaxpr(contract.target)(*args)
    except Exception:
        jaxpr = None  # numpy host fn — fall through to the probe
    if jaxpr is not None:
        bad = _walk_jaxpr(jaxpr.jaxpr) & _CROSS_VERTEX_PRIMITIVES
        if bad:
            return [Finding(
                "TC001", where, 0,
                f"@elementwise fn traces cross-vertex primitives "
                f"{sorted(bad)} — out[i] must depend on in[i] only")]
    # concrete slicewise probe (also exercises numpy host fns): full
    # output row i must equal the output of the length-1 slice at i
    try:
        full = np.asarray(contract.target(*args))
    except Exception as e:
        return [Finding("TC001", where, 0,
                        f"@elementwise fn failed on probe inputs: {e!r}")]
    if full.shape[:1] != (n,):
        return [Finding(
            "TC001", where, 0,
            f"@elementwise fn returned leading axis {full.shape[:1]} "
            f"for input axis ({n},) — must map axis 0 one-to-one")]
    for i in range(n):
        sliced = [a[i:i + 1] if isinstance(a, np.ndarray) else a
                  for a in args]
        row = np.asarray(contract.target(*sliced))[0]
        if not np.allclose(full[i], row, rtol=1e-6, atol=1e-6,
                           equal_nan=True):
            return [Finding(
                "TC001", where, 0,
                f"@elementwise violated at vertex {i}: full-batch row "
                f"{full[i]!r} != single-slice result {row!r}")]
    return []


# -- structure_independent ---------------------------------------------------
def _two_graphs(n: int = 64):
    from repro.core import graph as G
    return (G.uniform_graph(n, deg=4, seed=0, weighted=True),
            G.uniform_graph(n, deg=6, seed=3, weighted=True))


def check_structure_independent(contract: Contract) -> list[Finding]:
    where = f"{contract.module}:{contract.qualname}"
    params = list(inspect.signature(contract.target).parameters)
    if params and params[0] == "n":
        # lane_init(n, params): cannot see structure by construction;
        # probe determinism (bitwise-equal repeat calls)
        n = 64
        lane_params = ([3, 17, 41] if "pagerank" not in contract.qualname
                       else [[3, 17], [5], [9, 11, 13]])
        a = contract.target(n, lane_params)[0]
        b = contract.target(n, lane_params)[0]
        if not np.array_equal(a, b):
            return [Finding("TC002", where, 0,
                            "@structure_independent lane_init is not "
                            "deterministic across repeat calls")]
        return []
    g1, g2 = _two_graphs()
    try:
        v1 = np.asarray(contract.target(g1)[0])
        v2 = np.asarray(contract.target(g2)[0])
    except Exception as e:
        return [Finding("TC002", where, 0,
                        f"@structure_independent init failed: {e!r}")]
    if not np.array_equal(v1, v2):
        diff = int((v1 != v2).sum())
        return [Finding(
            "TC002", where, 0,
            f"@structure_independent init VALUES differ on two graphs "
            f"with the same n ({diff}/{v1.size} entries) — values must "
            f"be a function of n and program parameters only")]
    return []


# -- decision_identical ------------------------------------------------------
def check_decision_identical(contract: Contract) -> list[Finding]:
    where = f"{contract.module}:{contract.qualname}"
    twin = contract.meta.get("twin")
    if twin is None or not callable(twin):
        return [Finding("TC003", where, 0,
                        "@decision_identical has no callable twin")]
    if contract.qualname != "make_device_select":
        # other decision-identical pairs (the streaming successors
        # oracle) are enforced by their hypothesis property suites; the
        # contract marker records the pairing
        return []
    from repro.core.schedule import Scheduler
    rng = np.random.default_rng(11)
    width, cold_frac, min_psd = 4, 0.25, np.float32(1e-6)
    select = contract.target(width, cold_frac, float(min_psd), pad_id=0)
    sched = Scheduler(width=width, i2=3, cold_frac=cold_frac,
                      min_psd=float(min_psd))
    for trial in range(20):
        p = 8 if trial % 2 == 0 else 5
        shape = (p,) if trial % 3 else (p, 2)
        psd = (rng.random(shape) * rng.integers(0, 3, shape)
               ).astype(np.float32)
        is_hot = rng.random(p) < 0.5
        for it in range(4):
            hr, hok, cr, cok = select(jnp.int32(it), jnp.int32(sched.i2),
                                      jnp.asarray(psd),
                                      jnp.asarray(is_hot))
            sel = sched.select(it, psd, is_hot)
            dev_hot = np.asarray(hr)[np.asarray(hok)]
            dev_cold = np.asarray(cr)[np.asarray(cok)]
            if not (np.array_equal(dev_hot, sel.hot_ids)
                    and np.array_equal(dev_cold, sel.cold_ids)):
                return [Finding(
                    "TC003", where, 0,
                    f"device select diverged from host twin at trial "
                    f"{trial} it {it}: device hot={dev_hot.tolist()} "
                    f"cold={dev_cold.tolist()} vs host "
                    f"hot={sel.hot_ids.tolist()} "
                    f"cold={sel.cold_ids.tolist()}")]
    return []


# -- one_executable_per ------------------------------------------------------
def _tiny_engine(use_pallas: bool = False):
    from repro.core.algorithms import pagerank
    from repro.core.engine import EngineConfig, StructureAwareEngine
    g, _ = _two_graphs(200)
    return StructureAwareEngine(g, pagerank(),
                                EngineConfig(block_size=64, width=2,
                                             use_pallas=use_pallas))


def check_one_executable_per(contracts: list[Contract]) -> list[Finding]:
    """Single driver for every registered compile-cache getter: the
    getters are lazy (jax.jit wrapping compiles nothing until called),
    so identity + cache-size checks are cheap."""
    if not contracts:
        return []
    out = []
    eng = _tiny_engine()
    from repro.core.algorithms import k_source_sssp
    from repro.serve.lanes import LaneEngine
    lane = LaneEngine(eng, k_source_sssp())

    def probe(obj, getter, *argsets):
        name = f"{getter.__module__}:{getter.__qualname__}"
        for args in argsets:
            first = getter(obj, *args)
            size = len(obj._fns)
            again = getter(obj, *args)
            if again is not first:
                out.append(Finding(
                    "TC004", name, 0,
                    f"@one_executable_per returned a fresh executable "
                    f"on repeat call with key args {args!r}"))
            elif len(obj._fns) != size:
                out.append(Finding(
                    "TC004", name, 0,
                    f"@one_executable_per grew the compile cache on a "
                    f"repeat call with key args {args!r}"))

    by_name = {c.qualname: c for c in contracts}
    for qual, c in by_name.items():
        fn = c.target
        if qual.startswith("StructureAwareEngine._get_chunk"):
            probe(eng, fn, (2,), (None,), (2, 16))
        elif qual.startswith("StructureAwareEngine._get_fn"):
            probe(eng, fn, (True, 2), (False, 2))
        elif qual.startswith("LaneEngine._get_chunk"):
            probe(lane, fn, (2,))
        elif qual == "make_block_sweep":
            # module-level builder with its own memo (not an obj._fns
            # cache): a repeat build over the same (program, geometry,
            # mode) must return the identical sweep closure
            from repro.kernels import block_sweep as bs
            store = eng.plan.unified
            args = (eng.program, store.tile_start, store.tile_cnt)
            kwargs = dict(n_tiles=int(store.src.shape[0]),
                          tile_w=int(store.src.shape[1]),
                          block_size=eng.plan.block_size,
                          n_total=eng.plan.graph.n)
            first = fn(*args, **kwargs)
            size = len(bs._BUILDER_CACHE)
            again = fn(*args, **kwargs)
            if again is not first:
                out.append(Finding(
                    "TC004", f"{c.module}:{qual}", 0,
                    "@one_executable_per kernel builder minted a fresh "
                    "sweep closure on a repeat build"))
            elif len(bs._BUILDER_CACHE) != size:
                out.append(Finding(
                    "TC004", f"{c.module}:{qual}", 0,
                    "@one_executable_per kernel builder cache grew on a "
                    "repeat build"))
        elif qual.startswith("StructureAwareEngine._chunked_scatter"):
            # exercised through update_edge_rows: same scatter key twice
            rows = np.array([0], dtype=np.int32)
            t = eng._ed.src.shape[1]
            payload = dict(src=np.zeros((1, t), np.int32),
                           dst_local=np.zeros((1, t), np.int32),
                           w=np.zeros((1, t), np.float32),
                           valid=np.zeros((1, t), bool))
            eng.update_edge_rows(rows, **payload)
            size = len(eng._fns)
            eng.update_edge_rows(rows, **payload)
            if len(eng._fns) != size:
                out.append(Finding(
                    "TC004", f"{c.module}:{qual}", 0,
                    "@one_executable_per scatter cache grew on an "
                    "identical repeat scatter"))
    return out


# -- golden jaxprs -----------------------------------------------------------
def _canonical_hash(jaxpr) -> str:
    text = re.sub(r"\s+", " ", str(jaxpr)).strip()
    return hashlib.sha256(text.encode()).hexdigest()[:16]


def golden_entries() -> dict[str, str]:
    """Trace the compiled entry points on a tiny deterministic engine and
    hash the canonicalized jaxprs. Tracing only — nothing compiles."""
    from repro.core.schedule import make_device_select
    eng = _tiny_engine()
    from repro.core.algorithms import k_source_sssp
    from repro.serve.lanes import LaneEngine
    lane = LaneEngine(eng, k_source_sssp())
    p = eng.plan
    w = 2
    entries: dict[str, str] = {}

    select = make_device_select(4, 0.25, 1e-6, pad_id=0)
    entries["device_select_w4"] = _canonical_hash(jax.make_jaxpr(select)(
        jnp.int32(0), jnp.int32(4),
        jax.ShapeDtypeStruct((8, 2), jnp.float32),
        jax.ShapeDtypeStruct((8,), jnp.bool_)))

    hot, cold = eng._sweeps(w)
    values = jax.ShapeDtypeStruct((eng._values_len,), jnp.float32)
    ps = jax.ShapeDtypeStruct((p.num_blocks, eng.config.subblocks),
                              jnp.float32)
    rows = jax.ShapeDtypeStruct((w,), jnp.int32)
    ok = jax.ShapeDtypeStruct((w,), jnp.bool_)
    entries["tiled_hot_sweep_w2"] = _canonical_hash(
        jax.make_jaxpr(hot)(eng._ed, values, ps, ps, rows, ok))
    entries["tiled_cold_sweep_w2"] = _canonical_hash(
        jax.make_jaxpr(cold)(eng._ed, values, ps, ps, rows, ok))

    counts = jax.ShapeDtypeStruct((p.num_blocks, eng.config.subblocks),
                                  jnp.int32)
    hslots = jax.ShapeDtypeStruct((p.num_blocks,), jnp.int32)
    entries["fused_chunk_w2"] = _canonical_hash(jax.make_jaxpr(
        eng._get_chunk(w))(
        eng._ed, eng._coupling_dev, values, ps, ps, counts, hslots,
        jax.ShapeDtypeStruct((w,), jnp.int32), jnp.int32(0), jnp.int32(0),
        jnp.int32(0), jax.ShapeDtypeStruct((p.num_blocks,), jnp.bool_),
        jnp.int32(4)))

    # the traced fused chunk (history-buffer variant behind
    # engine.run(trace=True)): extra int32 accounting table + the two
    # history buffers in the carry. Its OWN golden pins the traced trace
    # structure; the untraced entry above staying bit-identical across
    # this PR is the proof that trace=None compiles to exactly the
    # historical loop.
    from repro.core.engine import (TIMELINE_FLOAT_COLS, TIMELINE_INT_COLS)
    cap = 16
    acct = jax.ShapeDtypeStruct((p.num_blocks, 4), jnp.int32)
    hist_i = jax.ShapeDtypeStruct((cap, len(TIMELINE_INT_COLS)), jnp.int32)
    hist_f = jax.ShapeDtypeStruct((cap, len(TIMELINE_FLOAT_COLS)),
                                  jnp.float32)
    entries["fused_chunk_traced_w2_c16"] = _canonical_hash(jax.make_jaxpr(
        eng._get_chunk(w, cap))(
        eng._ed, eng._coupling_dev, values, ps, ps, counts, hslots,
        jax.ShapeDtypeStruct((w,), jnp.int32), jnp.int32(0), jnp.int32(0),
        jnp.int32(0), jax.ShapeDtypeStruct((p.num_blocks,), jnp.bool_),
        jnp.int32(4), acct, hist_i, hist_f))

    # lane chunk (serve path): chunk(ed, coupling, vconst, values, psd,
    # dmax, calm, counts, hslots, sbacc, lane_done, lane_it, it0, it_end,
    # is_hot, i2); at subblocks == 1 the lane psd/dmax are (P, L) and
    # calm/counts are (P,)
    nl = 2
    lvals = jax.ShapeDtypeStruct((eng._values_len, nl), jnp.float32)
    lps = jax.ShapeDtypeStruct((p.num_blocks, nl), jnp.float32)
    pvec_i = jax.ShapeDtypeStruct((p.num_blocks,), jnp.int32)
    entries["lane_chunk_w2_l2"] = _canonical_hash(jax.make_jaxpr(
        lane._get_chunk(w))(
        eng._ed, eng._coupling_dev, lvals, lvals, lps, lps,
        pvec_i, pvec_i, jax.ShapeDtypeStruct((w,), jnp.int32),
        jnp.int32(0),
        jax.ShapeDtypeStruct((nl,), jnp.bool_),
        jax.ShapeDtypeStruct((nl,), jnp.int32),
        jnp.int32(0), jnp.int32(0),
        jax.ShapeDtypeStruct((p.num_blocks,), jnp.bool_), jnp.int32(4)))

    # the fused Pallas sweep paths: same tiny geometry with
    # use_pallas=True — the hot/cold sweeps now wrap one pallas_call per
    # block and a silent change to the kernel's trace (grid, block specs,
    # in-kernel combine) must diff loudly here, exactly like the dense
    # entries above
    engp = _tiny_engine(use_pallas=True)
    hot_p, cold_p = engp._sweeps(w)
    entries["pallas_hot_sweep_w2"] = _canonical_hash(
        jax.make_jaxpr(hot_p)(engp._ed, values, ps, ps, rows, ok))
    entries["pallas_cold_sweep_w2"] = _canonical_hash(
        jax.make_jaxpr(cold_p)(engp._ed, values, ps, ps, rows, ok))
    lane_p = LaneEngine(engp, k_source_sssp())
    entries["pallas_lane_chunk_w2_l2"] = _canonical_hash(jax.make_jaxpr(
        lane_p._get_chunk(w))(
        engp._ed, engp._coupling_dev, lvals, lvals, lps, lps,
        pvec_i, pvec_i, jax.ShapeDtypeStruct((w,), jnp.int32),
        jnp.int32(0),
        jax.ShapeDtypeStruct((nl,), jnp.bool_),
        jax.ShapeDtypeStruct((nl,), jnp.int32),
        jnp.int32(0), jnp.int32(0),
        jax.ShapeDtypeStruct((p.num_blocks,), jnp.bool_), jnp.int32(4)))

    # the donated row scatter (streaming commit path): same closure the
    # engine builds lazily in _chunked_scatter
    na = 5

    def row_scatter(*args):
        arrs, r, payloads = args[:na], args[na], args[na + 1:]
        return tuple(a.at[r].set(pl) for a, pl in zip(arrs, payloads))

    t = eng._ed.src.shape[1]
    chunk = 16

    def tile(dt):
        return jax.ShapeDtypeStruct(eng._ed.src.shape, dt)

    def pay(dt):
        return jax.ShapeDtypeStruct((chunk, t), dt)

    entries["row_scatter_c16"] = _canonical_hash(jax.make_jaxpr(
        row_scatter)(
        tile(jnp.int32), tile(jnp.int32), tile(jnp.float32),
        tile(jnp.bool_),
        jax.ShapeDtypeStruct(eng._ed.cov.shape, jnp.bool_),
        jax.ShapeDtypeStruct((chunk,), jnp.int32),
        pay(jnp.int32), pay(jnp.int32), pay(jnp.float32), pay(jnp.bool_),
        jax.ShapeDtypeStruct((chunk, eng._ed.cov.shape[1]), jnp.bool_)))
    return entries


def write_golden(path: Path = GOLDEN_PATH) -> dict:
    payload = {"jax_version": jax.__version__,
               "entries": golden_entries()}
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    return payload


def check_golden(path: Path = GOLDEN_PATH) -> tuple[list[Finding], str]:
    """Returns (findings, status). Status is 'ok', 'skipped', or
    'missing'."""
    if not path.exists():
        return ([Finding(
            "TC005", str(path), 0,
            "golden_jaxprs.json missing — run `python -m repro.analysis "
            "--update-golden` and commit the result")], "missing")
    stored = json.loads(path.read_text())
    if stored.get("jax_version") != jax.__version__:
        return ([], "skipped")
    current = golden_entries()
    out = []
    for name, want in sorted(stored.get("entries", {}).items()):
        got = current.get(name)
        if got is None:
            out.append(Finding(
                "TC005", str(path), 0,
                f"golden entry '{name}' no longer traceable — if the "
                f"entry point moved intentionally, regenerate with "
                f"--update-golden"))
        elif got != want:
            out.append(Finding(
                "TC005", str(path), 0,
                f"trace structure of '{name}' changed "
                f"({want} -> {got}) — if intentional, regenerate with "
                f"`python -m repro.analysis --update-golden` and commit"))
    for name in sorted(set(current) - set(stored.get("entries", {}))):
        out.append(Finding(
            "TC005", str(path), 0,
            f"new golden entry '{name}' not in committed file — "
            f"regenerate with --update-golden"))
    return (out, "ok")


# -- driver ------------------------------------------------------------------
def check_contracts(contracts: list[Contract]) -> list[Finding]:
    findings: list[Finding] = []
    oep = []
    for c in contracts:
        if c.kind == "elementwise":
            findings += check_elementwise(c)
        elif c.kind == "structure_independent":
            findings += check_structure_independent(c)
        elif c.kind == "decision_identical":
            findings += check_decision_identical(c)
        elif c.kind == "one_executable_per":
            oep.append(c)
        # @deterministic is enforced by the lint layer (RA004)
    findings += check_one_executable_per(oep)
    return findings
