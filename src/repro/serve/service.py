"""QueryService: batched multi-source query sessions over a live graph.

The read-side counterpart of :class:`repro.stream.StreamingEngine` (which
handles the write side): user-style queries — k-source SSSP/BFS
traversals, personalized PageRank — are admitted into **lane slots**,
batched by compatible program family, and executed as one fused
multi-lane run per batch (:class:`repro.serve.lanes.LaneEngine`), so L
queries pay one schedule, one partition-load stream, and one while-loop.

Session model (all synchronous, deterministic — "concurrency" is
interleaving of submits, ingests, and runs):

  * ``submit(query)`` pins the CURRENT streaming epoch (snapshot
    isolation: the answer is computed on the graph as of submission, no
    matter how many delta batches land before the query runs);
  * ``ingest(batch)`` forwards to the streaming engine, whose preamble
    device-copies the pinned epoch state before the donated commits can
    mutate it — in-flight lanes keep reading consistent edge data;
  * ``run_pending()`` groups pending queries by (epoch, family), orders
    admission by the paper's activity priority (hottest frontier first —
    ``schedule.admission_order``), packs them into lane batches of
    ``max_lanes`` (padded to a fixed width so one compiled executable
    serves the steady state), and executes each batch on its pinned
    epoch.

One LaneEngine is kept per (engine epoch-geometry, family): epochs that
only mutate edge data in place re-enter the already-compiled lane
superstep; only a tile-overflow plan rebuild recompiles — exactly the
streaming engine's own compile story.

Out-of-core budgets (``EngineConfig.resident_blocks``): pinned epochs
survive eviction. The spill tier's pre-eviction hook preserves every
live pin before the eviction scatter invalidates device rows, and a pin
taken while blocks are already spilled materializes the holes from the
tier's truth (``StreamingEngine.snapshot`` / ``EpochState.ed``) — so
lane batches always read a complete, consistent edge state even when the
live engine holds only a fraction of the graph resident.
"""
from __future__ import annotations

import dataclasses
import time
import weakref

import jax.numpy as jnp
import numpy as np

from repro.core.algorithms import LANE_FAMILIES, LaneProgram
from repro.core.engine import coupling_from_counts
from repro.core.metrics import ServeMetrics, Timer
from repro.core.schedule import admission_order
from repro.obs import trace as obs_trace
from repro.serve.lanes import LaneEngine
from repro.stream.delta import DeltaBatch
from repro.stream.engine import EpochState, StreamingEngine


@dataclasses.dataclass(frozen=True)
class Query:
    """One user query. ``kind`` picks the lane family:

      * ``sssp`` / ``bfs`` — single-source traversal from ``source``;
      * ``ppr`` — personalized PageRank restarting into ``reset`` (vertex
        ids, uniform over the set; or a dense (n,) distribution), with
        ``damping``.
    """

    kind: str
    source: int | None = None
    reset: object = None
    damping: float = 0.85

    def lane_param(self):
        if self.kind in ("sssp", "bfs"):
            return self.source
        return np.asarray(self.reset)

    def family_key(self) -> tuple:
        return ((self.kind, self.damping) if self.kind == "ppr"
                else (self.kind,))


@dataclasses.dataclass
class QueryResult:
    query_id: int
    kind: str
    epoch: int  # the pinned epoch the answer is consistent with
    values: np.ndarray  # (n,), original vertex ids
    iterations: int  # supersteps until THIS lane's convergence mask set
    batch_iterations: int  # supersteps of the whole lane batch
    lanes: int  # admitted lanes in the batch that served this query
    run_s: float  # the batch's execution wall time
    wait_s: float  # submit -> completion, minus the batch run time
    converged: bool

    @property
    def latency_s(self) -> float:
        return self.wait_s + self.run_s


@dataclasses.dataclass
class _Pending:
    qid: int
    query: Query
    epoch_state: EpochState  # strong ref: keeps the pin alive until served
    t_submit: float
    priority: float


class QueryService:
    """Long-lived query façade over one StreamingEngine."""

    def __init__(self, streaming: StreamingEngine, max_lanes: int = 8,
                 prewarm: bool = True, use_pallas: bool | None = None):
        if max_lanes < 1:
            raise ValueError("max_lanes must be >= 1")
        self.streaming = streaming
        self.max_lanes = max_lanes
        # None defers to each epoch engine's EngineConfig.use_pallas
        self.use_pallas = use_pallas
        self.n = streaming.n
        self.metrics = ServeMetrics()
        self._prewarm = prewarm
        self._pending: list[_Pending] = []
        self._epoch_state: EpochState | None = None
        # engine-geometry -> {family_key -> LaneEngine}; weak so a plan
        # rebuild lets the old epoch's executables die with its last pin
        self._lane_engines: "weakref.WeakKeyDictionary" = \
            weakref.WeakKeyDictionary()
        self._next_id = 0
        self._epochs_pinned: set[int] = set()

    # -- admission -----------------------------------------------------------
    def submit(self, query: Query) -> int:
        """Admit a query; pins the current epoch and returns a query id."""
        family = self._family(query.family_key())
        if family.needs_symmetric != self.streaming.program.needs_symmetric:
            raise ValueError(
                f"family {family.name} needs_symmetric="
                f"{family.needs_symmetric} does not match the host "
                "program's storage — symmetric and asymmetric tile layouts "
                "cannot share an epoch")
        if query.kind in ("sssp", "bfs"):
            if not (query.source is not None
                    and 0 <= int(query.source) < self.n):
                raise ValueError(f"query source must be in [0, {self.n})")
        else:
            self._validate_reset(query.reset)
        es = self._pin()
        qid = self._next_id
        self._next_id += 1
        self._pending.append(_Pending(
            qid=qid, query=query, epoch_state=es,
            t_submit=time.perf_counter(),
            priority=self._priority(query, es)))
        if es.epoch not in self._epochs_pinned:
            self._epochs_pinned.add(es.epoch)
            self.metrics.epochs_pinned += 1
        return qid

    @property
    def pending(self) -> int:
        return len(self._pending)

    def ingest(self, batch: DeltaBatch):
        """Forward a delta batch to the write side. Pending queries keep
        their pinned epoch (the streaming preamble preserves it)."""
        self._epoch_state = None  # next submit pins the new epoch
        return self.streaming.ingest(batch)

    # -- execution -----------------------------------------------------------
    def run_pending(self) -> list[QueryResult]:
        """Execute every pending query, batched by (epoch, family), lanes
        admitted hottest-frontier-first. Returns results in completion
        order (batch by batch)."""
        groups: dict[tuple, list[_Pending]] = {}
        for p in self._pending:
            groups.setdefault((p.epoch_state.epoch, p.query.family_key()),
                              []).append(p)
        self._pending = []
        # drop the admission cache: with nothing pending, holding the pin
        # would force the next ingest to device-copy an epoch nobody will
        # read (a later submit re-pins for the cost of the host copies)
        self._epoch_state = None
        plan: list[list[_Pending]] = []
        for key in sorted(groups, key=lambda k: (k[0], k[1])):
            batch = groups[key]
            order = admission_order(np.array([p.priority for p in batch]))
            ranked = [batch[i] for i in order]
            plan.extend(ranked[at:at + self.max_lanes]
                        for at in range(0, len(ranked), self.max_lanes))
        results: list[QueryResult] = []
        for i, batch in enumerate(plan):
            try:
                results.extend(self._run_batch(batch))
            except Exception:
                # a failing batch consumes only its own queries (the error
                # propagates with them); everything not yet served goes
                # back on the queue instead of being silently discarded
                for rest in plan[i + 1:]:
                    self._pending.extend(rest)
                raise
        return results

    def _run_batch(self, pend: list[_Pending]) -> list[QueryResult]:
        es = pend[0].epoch_state
        query0 = pend[0].query
        family = self._family(query0.family_key())
        lane_eng = self._lane_engine(es, query0.family_key(), family)
        k = len(pend)
        # pad to the fixed lane width: one compiled executable per family;
        # padding lanes start individually converged (masked slots, like
        # dispatch-width padding) and are never billed
        params = [p.query.lane_param() for p in pend]
        params += [params[0]] * (self.max_lanes - k)
        lane_active = np.zeros(self.max_lanes, dtype=bool)
        lane_active[:k] = True
        values0, vconst = family.lane_init(self.n, params)
        aux = (family.aux_fn(es.out_deg, es.in_deg)
               if family.aux_fn is not None
               else np.zeros(es.out_deg.shape[0], np.float32))
        ed = es.ed._replace(aux=jnp.asarray(np.asarray(aux, np.float32)))
        coupling = coupling_from_counts(
            es.coupling_counts, family, es.engine.plan.block_size)
        with obs_trace.span("query_batch", cat="serve", lanes=k,
                            family=query0.family_key()[0],
                            epoch=es.epoch) as sp, Timer() as t:
            res = lane_eng.run(ed=ed, coupling=coupling, values0=values0,
                               vconst=vconst, lane_active=lane_active,
                               edge_counts=es.edge_counts)
            sp.set(iterations=res.metrics.iterations)
        done_at = time.perf_counter()
        out: list[QueryResult] = []
        for lane, p in enumerate(pend):
            out.append(QueryResult(
                query_id=p.qid, kind=p.query.kind, epoch=es.epoch,
                values=res.values[:, lane],
                iterations=int(res.lane_iterations[lane]),
                batch_iterations=res.metrics.iterations, lanes=k,
                run_s=t.elapsed,
                wait_s=max(done_at - p.t_submit - t.elapsed, 0.0),
                converged=bool(res.lane_converged[lane])))
        m = self.metrics
        m.queries += k
        m.lane_batches += 1
        m.lanes_admitted += k
        m.lane_slots += self.max_lanes
        m.run_time_s += t.elapsed
        m.wait_time_s += sum(r.wait_s for r in out)
        m.iterations += res.metrics.iterations
        m.blocks_retired += res.metrics.blocks_retired
        m.stale_answers += k if es.epoch < self.streaming.epoch else 0
        return out

    # -- internals -----------------------------------------------------------
    def _validate_reset(self, reset) -> np.ndarray:
        """Admission-time validation of a ppr personalization: either a
        dense (n,) float distribution or a non-empty id set within
        [0, n). Returns the seed vertex ids (priority scoring reuses
        them). Rejecting here keeps a malformed query from detonating
        inside run_pending, where it would take its lane batch with it."""
        if reset is None:
            raise ValueError("ppr query needs a reset set")
        rs = np.asarray(reset)
        if rs.ndim == 1 and rs.size == self.n and rs.dtype.kind == "f":
            col = rs.astype(np.float64)
            if col.min() < 0 or not np.isclose(col.sum(), 1.0, rtol=1e-4):
                raise ValueError("dense ppr reset must be a distribution "
                                 "(non-negative, summing to 1)")
            return np.flatnonzero(rs > 0)
        ids = rs.astype(np.int64).reshape(-1)
        if ids.size == 0 or ids.min() < 0 or ids.max() >= self.n:
            raise ValueError("ppr reset must be non-empty vertex ids in "
                             f"[0, {self.n}) or a dense (n,) distribution")
        return ids

    def _pin(self) -> EpochState:
        es = self._epoch_state
        if es is None or es.epoch != self.streaming.epoch:
            es = self.streaming.snapshot()
            self._epoch_state = es
        return es

    @staticmethod
    def _family(key: tuple) -> LaneProgram:
        kind = key[0]
        if kind not in LANE_FAMILIES:
            raise ValueError(f"unknown query kind {kind!r} "
                             f"(have {sorted(LANE_FAMILIES)})")
        return (LANE_FAMILIES[kind](damping=key[1]) if kind == "ppr"
                else LANE_FAMILIES[kind]())

    def _lane_engine(self, es: EpochState, key: tuple,
                     family: LaneProgram) -> LaneEngine:
        per_engine = self._lane_engines.get(es.engine)
        if per_engine is None:
            per_engine = {}
            self._lane_engines[es.engine] = per_engine
        eng = per_engine.get(key)
        if eng is None:
            eng = LaneEngine(es.engine, family, use_pallas=self.use_pallas)
            if self._prewarm:
                eng.prewarm(self.max_lanes)
            per_engine[key] = eng
        return eng

    def _priority(self, query: Query, es: EpochState) -> float:
        """Admission priority: the pinned epoch's activity D(v) = out +
        alpha * in of the query's seed vertices (max over a ppr reset
        set) — the same Eq. 1 quantity that ranks unseen blocks, applied
        at the admission queue (hottest frontier claims a lane first)."""
        plan = es.engine.plan
        if query.kind in ("sssp", "bfs"):
            seeds = np.array([int(query.source)])
        else:
            seeds = self._validate_reset(query.reset)
            if seeds.size == 0:  # dense vector with empty support
                return 0.0
        perm = plan.inv[seeds]
        act = es.out_deg[perm] + plan.alpha * es.in_deg[perm]
        return float(act.max())
