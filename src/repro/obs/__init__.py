"""Structure-aware observability: span tracing + superstep timelines.

Two halves:

  * device side — ``engine.run(trace=True)`` grows the fused while_loop
    carry with a bounded per-superstep history buffer (counter deltas,
    dispatch width, retirements, PSD stats) flushed at the existing
    repartition-boundary sync and surfaced as ``RunResult.timeline``;
  * host side — :class:`TraceRecorder` collects nested spans (``run``,
    ``repartition``, ``ingest``, ``spill_evict``/``prefetch``,
    ``snapshot``, ``query_batch``) from engine/stream/serve/ooc into a
    ring buffer, exported as Chrome-trace/Perfetto JSON
    (:mod:`repro.obs.export`) and rendered by ``python -m repro.obs``.

Typical capture::

    from repro.obs import trace, export
    with trace.recording() as rec:
        service.run_pending()          # spans auto-attach
    export.write(rec, "results/trace_serve.json")

or ``python -m benchmarks.run --trace`` for whole bench suites.
"""
from repro.obs.trace import (TraceRecorder, current, install,  # noqa: F401
                             recording, span, uninstall)
from repro.obs.export import to_chrome, validate, write  # noqa: F401
