"""Serving table: batched multi-lane query execution vs sequential
single-lane runs over the same live StreamingEngine.

The tentpole claim: L compatible queries executed as lanes of ONE fused
run (shared partition loads, shared schedule, shared while-loop) deliver
a multiple of the throughput of the same L queries run one-at-a-time
through the identical fused machinery:

  * ``serve_batched``     — QueryService(max_lanes=L): one lane batch;
  * ``serve_sequential``  — QueryService(max_lanes=1): L single-lane
                            batches, same compiled-steady-state protocol
                            (both services are warmed first, so the ratio
                            isolates lane batching, not compile noise);
  * ``serve_under_churn`` — queries interleaved with delta-batch ingests:
                            epoch pins answer on their frozen snapshots
                            while the graph mutates underneath.

us_per_call is wall time per QUERY; derived carries queries/s, p50/p95
per-query latency, and the batched row's speedup_vs_sequential (the
acceptance number: >= 3x at n=20000, powerlaw, L=8).
"""
from __future__ import annotations

import numpy as np

from repro.core import algorithms as A
from repro.core import graph as G
from repro.core.engine import EngineConfig
from repro.core.metrics import Timer
from repro.serve import Query, QueryService
from repro.stream import StreamingEngine, synthetic_stream


def _queries(kind: str, n: int, k: int, seed: int = 0) -> list[Query]:
    rng = np.random.default_rng(seed)
    seeds = rng.choice(n, size=k, replace=False)
    if kind == "sssp":
        return [Query(kind="sssp", source=int(s)) for s in seeds]
    return [Query(kind="ppr", reset=[int(s), int((s + 1) % n)])
            for s in seeds]


def _measure(svc: QueryService, queries: list[Query]):
    """One measured pass: submit everything, run, return (wall, results)."""
    with Timer() as t:
        for q in queries:
            svc.submit(q)
        res = svc.run_pending()
    return t.elapsed, res


def _pcts(res) -> tuple[float, float]:
    lat = np.array([r.latency_s for r in res])
    return float(np.percentile(lat, 50)), float(np.percentile(lat, 95))


def run(n: int = 20000, lanes: int = 8):
    cfg = EngineConfig(t2=1e-8, width=16, block_size=512)
    g = G.powerlaw_graph(n, avg_deg=8, seed=1, weighted=True)
    se = StreamingEngine(g, A.pagerank(), cfg)
    rows = []

    for kind in ("sssp", "ppr"):
        queries = _queries(kind, n, lanes, seed=3)
        batched = QueryService(se, max_lanes=lanes)
        seq = QueryService(se, max_lanes=1)
        # steady-state protocol: one warmup pass through each service
        # (compiles every width bucket + the lane executables), then the
        # measured pass — the serving ratio, not the compile ratio
        _measure(batched, queries)
        _measure(seq, queries)
        wall_b, res_b = _measure(batched, queries)
        wall_s, res_s = _measure(seq, queries)
        vb = np.stack([r.values for r in
                       sorted(res_b, key=lambda r: r.query_id)])
        vs = np.stack([r.values for r in
                       sorted(res_s, key=lambda r: r.query_id)])
        agree = np.allclose(np.minimum(vb, 1e18), np.minimum(vs, 1e18),
                            rtol=1e-4, atol=1e-5)
        p50b, p95b = _pcts(res_b)
        p50s, p95s = _pcts(res_s)
        iters_b = max(r.batch_iterations for r in res_b)
        iters_s = sum(r.batch_iterations for r in res_s)
        rows.append((
            f"serve/powerlaw/k{kind}/serve_batched", wall_b * 1e6 / lanes,
            f"lanes={lanes};queries={lanes};qps={lanes / wall_b:.2f};"
            f"p50_ms={p50b * 1e3:.0f};p95_ms={p95b * 1e3:.0f};"
            f"iters={iters_b};agree={agree};"
            f"speedup_vs_sequential={wall_s / max(wall_b, 1e-9):.2f}x"))
        rows.append((
            f"serve/powerlaw/k{kind}/serve_sequential", wall_s * 1e6 / lanes,
            f"lanes=1;queries={lanes};qps={lanes / wall_s:.2f};"
            f"p50_ms={p50s * 1e3:.0f};p95_ms={p95s * 1e3:.0f};"
            f"iters={iters_s}"))

    # mixed traffic: queries pinned across live ingests (snapshot
    # isolation paid for real: the preamble device-copies pinned epochs)
    churn = QueryService(se, max_lanes=lanes)
    qs = _queries("sssp", n, lanes, seed=9)
    _measure(churn, qs)  # warm
    deltas = synthetic_stream(se.current_graph(), 2, 200, seed=4,
                              delete_frac=0.2, weighted=True)
    pre = se.metrics.snapshots_preserved
    with Timer() as t:
        for q in qs[:lanes // 2]:
            churn.submit(q)
        churn.ingest(deltas[0])
        for q in qs[lanes // 2:]:
            churn.submit(q)
        churn.ingest(deltas[1])
        res = churn.run_pending()
    p50, p95 = _pcts(res)
    epochs = sorted({r.epoch for r in res})
    rows.append((
        "serve/powerlaw/ksssp/serve_under_churn", t.elapsed * 1e6 / len(qs),
        f"lanes={lanes};queries={len(qs)};ingests=2;"
        f"qps={len(qs) / t.elapsed:.2f};p50_ms={p50 * 1e3:.0f};"
        f"p95_ms={p95 * 1e3:.0f};epochs={epochs};"
        f"pins_preserved={se.metrics.snapshots_preserved - pre};"
        f"stale_answers={churn.metrics.stale_answers}"))
    return rows
