"""Static contract analysis for the structure-aware engine.

Three layers, one CLI (``python -m repro.analysis``):

  * :mod:`repro.analysis.contracts` — machine-readable contract markers
    (``@elementwise``, ``@structure_independent``,
    ``@decision_identical``, ``@one_executable_per``,
    ``@deterministic``) and the registry ``discover()`` walks;
  * :mod:`repro.analysis.lint` — repo-specific AST rules over
    ``src/repro`` (host syncs inside traced code, reads after donation,
    loop-varying closure captures in jitted functions, nondeterminism in
    schedule-affecting modules);
  * :mod:`repro.analysis.tracecheck` — abstract-eval enforcement of the
    registered contracts plus golden-jaxpr hashing of the compiled entry
    points (``golden_jaxprs.json``).

Import cost matters: this package is imported by the engine modules for
the decorators, so ``contracts`` must stay stdlib-only (``lint`` and
``tracecheck`` are only imported by the CLI and tests).
"""
from repro.analysis.contracts import (Contract, decision_identical,
                                      deterministic, discover, elementwise,
                                      one_executable_per, registry,
                                      structure_independent)

__all__ = ["Contract", "decision_identical", "deterministic", "discover",
           "elementwise", "one_executable_per", "registry",
           "structure_independent"]
