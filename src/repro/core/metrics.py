"""Accounting the paper evaluates on: runtime, updates, partition loads.

On TPU/CPU we cannot read an L3-miss counter, but the schedule makes the
quantity *exact*: every scheduled block is one partition load (HBM->VMEM
refill of its edge slice + vertex slice). ``bytes_loaded`` is the I/O proxy
(paper §2.1), ``updates`` the convergence-work proxy (§2.2 contribution 1).
"""
from __future__ import annotations

import dataclasses
import time


# Order of the accounting vector the fused engine flushes at repartition
# boundaries: the device accumulates exact per-block schedule counts, the
# host expands them through a per-block [vertices, edges, loads, bytes]
# table into this layout.
COUNTER_FIELDS = ("updates", "edges_processed", "block_loads",
                  "bytes_loaded")


def _with_properties(m) -> dict:
    """``dataclasses.asdict`` plus every ``@property`` on the class — the
    one serializer all three metrics classes share, so a derived quantity
    added to a class can never silently miss its report/JSON row
    (``tests/test_obs.py`` asserts the parity)."""
    d = dataclasses.asdict(m)
    for klass in reversed(type(m).__mro__):
        for name, attr in vars(klass).items():
            if isinstance(attr, property):
                d[name] = getattr(m, name)
    return d


def block_io_bytes(edges, block_size):
    """Shared I/O cost model — bytes loaded when a block is scheduled:
    4B src id + 4B weight + 4B dst offset per edge, plus the block's vertex
    values. The ONE definition consumed by the engine accounting, the plan,
    and the baseline, so the bytes_loaded columns can never desync."""
    return edges * 12 + block_size * 4


@dataclasses.dataclass
class Metrics:
    iterations: int = 0
    updates: int = 0  # vertex apply() executions
    edges_processed: int = 0
    block_loads: int = 0  # partition loads (cache/I-O proxy)
    bytes_loaded: int = 0
    wall_time_s: float = 0.0
    converged: bool = False
    # adaptive active-set audit trail: how much of the schedule the run
    # actually retired / narrowed / shallowed (zero on the dense path)
    blocks_retired: int = 0  # blocks individually converged-and-retired at end
    mean_dispatch_width: float = 0.0  # iteration-weighted dispatch bucket
    inner_depth_hist: dict = dataclasses.field(default_factory=dict)
    # hot-slot executions per Gauss-Seidel depth {t_inner: count}
    # hierarchical-partition audit trail (block-level fields above are
    # untouched for cross-PR comparability; both are 0/1.0-trivial when
    # subblocks == 1)
    subblocks_retired: int = 0  # sub-blocks retired at end (calm >= limit)
    mean_subblock_dispatch: float = 0.0  # live sub-blocks per block load
    # out-of-core residency accounting (all zero when the run is fully
    # resident — resident_blocks unset or >= P). These audit the spill
    # tier's traffic; they are NOT part of the algorithmic trajectory, so
    # the budget-vs-resident bitwise parity tests exclude them.
    spill_evictions: int = 0  # blocks evicted device -> spill tier
    bytes_spilled: int = 0  # tile-row bytes moved off-device
    prefetch_hits: int = 0  # scheduled-block demands already resident
    prefetch_misses: int = 0  # demand fetches the prefetcher missed
    bytes_fetched: int = 0  # tile-row bytes scattered back on demand/prefetch

    @property
    def prefetch_hit_rate(self) -> float:
        """Fraction of scheduled-block demands that were already resident
        when the superstep needed them (1.0 when nothing ever spilled)."""
        total = self.prefetch_hits + self.prefetch_misses
        return self.prefetch_hits / total if total else 1.0

    def as_dict(self) -> dict:
        return _with_properties(self)

    def absorb_counters(self, counters) -> None:
        """Add a (len(COUNTER_FIELDS),) device-counter flush (cumulative
        deltas, COUNTER_FIELDS order). Deltas arrive as exact int64s; no
        float round-trip, so totals stay exact at any scale."""
        for name, v in zip(COUNTER_FIELDS, counters):
            setattr(self, name, getattr(self, name) + int(v))


@dataclasses.dataclass
class StreamMetrics:
    """Cumulative accounting for a :class:`repro.stream.StreamingEngine`.

    The quantities the streaming claim rides on: per-batch latency, the
    dirty-block fraction (how much of the graph a delta actually
    re-heats), host->device upload bytes (how much of the mutated state
    actually moves), and edges reprocessed by the warm reconvergence — the
    number a cold full recompute is compared against.

    ``dirty_blocks`` / ``blocks_seen`` accumulate over IN-PLACE batches
    only: a tile-overflow batch re-heats every block by construction
    (``plan_rebuilds`` counts those), and folding it into the average
    would inflate ``dirty_frac`` past what the in-place path touches.
    """

    batches: int = 0
    ingest_time_s: float = 0.0  # delta application (storage mutation)
    reconverge_time_s: float = 0.0  # warm engine reconvergence
    edges_inserted: int = 0
    edges_deleted: int = 0  # deleted edge copies (incl. parallel edges)
    edges_reprocessed: int = 0  # engine edges_processed across warm runs
    iterations: int = 0  # warm reconvergence iterations across batches
    dirty_blocks: int = 0  # cumulative over in-place (non-rebuild) batches
    blocks_seen: int = 0  # cumulative P over in-place batches (denominator)
    appended_blocks: int = 0  # in-place tile appends (no rebuild)
    killed_blocks: int = 0  # in-place slot kills (no rebuild, no movement)
    rebuilt_blocks: int = 0  # per-block tile-run rebuilds (incl. compactions)
    aux_bumped_blocks: int = 0  # finite-PSD aux re-arms (not re-heated)
    plan_rebuilds: int = 0  # full overflow-triggered plan/storage rebuilds
    vertices_reset: int = 0  # non-monotone delete re-heat resets
    bytes_uploaded: int = 0  # actual host->device payload across batches
    bytes_full: int = 0  # what full per-batch re-uploads would have cost
    snapshots_preserved: int = 0  # epoch pins device-copied for isolation
    # adaptive active-set accounting across warm reconvergences
    blocks_retired: int = 0  # cumulative end-of-batch retired blocks
    width_iterations: float = 0.0  # sum of dispatch width over iterations
    inner_depth_hist: dict = dataclasses.field(default_factory=dict)
    # hierarchical-partition accounting (same in-place-batch convention as
    # dirty_blocks/blocks_seen; all 0 or degenerate when subblocks == 1)
    dirty_subblocks: int = 0  # cumulative armed sub-blocks (in-place batches)
    subblocks_seen: int = 0  # cumulative P*S over in-place batches
    subblocks_retired: int = 0  # cumulative end-of-batch retired sub-blocks
    subblock_loads: int = 0  # live sub-blocks actually swept across runs
    subblock_load_slots: int = 0  # block loads across warm runs (denominator)
    # out-of-core residency accounting across warm reconvergences (zero
    # when the engine runs fully resident)
    spill_evictions: int = 0
    bytes_spilled: int = 0
    prefetch_hits: int = 0
    prefetch_misses: int = 0
    bytes_fetched: int = 0

    @property
    def dirty_frac(self) -> float:
        return self.dirty_blocks / max(self.blocks_seen, 1)

    @property
    def subblock_dirty_frac(self) -> float:
        """Armed sub-blocks over sub-block slots (in-place batches): the
        granularity win over ``dirty_frac`` — a small delta arms few
        sub-blocks even when it pigeonholes into most blocks."""
        return self.dirty_subblocks / max(self.subblocks_seen, 1)

    @property
    def mean_subblock_dispatch(self) -> float:
        """Live sub-blocks swept per block load (1.0 when subblocks == 1):
        how much of each loaded block's vertex range actually computed."""
        return self.subblock_loads / max(self.subblock_load_slots, 1)

    @property
    def mean_dispatch_width(self) -> float:
        """Iteration-weighted mean dispatch-bucket width across batches —
        the claimed tail-superstep saving, auditable."""
        return self.width_iterations / max(self.iterations, 1)

    @property
    def prefetch_hit_rate(self) -> float:
        """Scheduled-block demands already resident, across warm runs
        (1.0 when nothing ever spilled)."""
        total = self.prefetch_hits + self.prefetch_misses
        return self.prefetch_hits / total if total else 1.0

    @property
    def upload_frac(self) -> float:
        return self.bytes_uploaded / max(self.bytes_full, 1)

    @property
    def latency_per_batch_s(self) -> float:
        return ((self.ingest_time_s + self.reconverge_time_s)
                / max(self.batches, 1))

    def as_dict(self) -> dict:
        return _with_properties(self)


@dataclasses.dataclass
class ServeMetrics:
    """Cumulative accounting for a :class:`repro.serve.QueryService`.

    The serving claims ride on three quantities: queries per second
    (lane batching amortizes partition loads and loop overhead over L
    queries), lane utilization (admitted lanes over lane slots — padding
    lanes are masked work), and how often snapshot isolation actually
    cost something (``epochs_pinned`` vs the stream side's
    ``snapshots_preserved``)."""

    queries: int = 0  # completed queries
    lane_batches: int = 0  # lane-engine runs executed
    lanes_admitted: int = 0  # real queries placed into lane slots
    lane_slots: int = 0  # total slots incl. padding (utilization denom)
    run_time_s: float = 0.0  # lane-engine wall time
    wait_time_s: float = 0.0  # submit -> completion minus own run time
    iterations: int = 0  # supersteps across lane batches
    epochs_pinned: int = 0  # distinct epochs queries pinned
    stale_answers: int = 0  # results served from a pre-ingest epoch
    blocks_retired: int = 0  # end-of-batch retired blocks across lane runs

    @property
    def lane_utilization(self) -> float:
        return self.lanes_admitted / max(self.lane_slots, 1)

    @property
    def queries_per_s(self) -> float:
        return self.queries / max(self.run_time_s, 1e-9)

    def as_dict(self) -> dict:
        return _with_properties(self)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.elapsed = time.perf_counter() - self.t0
