"""Streaming subsystem: delta generation, incremental storage mutation, and
the acceptance property — after every DeltaBatch the warm StreamingEngine
matches a cold StructureAwareEngine run on the mutated graph (PR + SSSP +
CC, including deletions, which exercise the non-monotone re-heat path)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import algorithms as A
from repro.core import graph as G
from repro.core.engine import EngineConfig, StructureAwareEngine
from repro.core.partition import build_tiled_storage
from repro.stream import (DeltaBatch, StreamConfig, StreamingEngine,
                          synthetic_stream)
from repro.stream.delta import apply_to_coo

CFG = EngineConfig(t2=1e-9, width=4, block_size=128)

PROGS = {"pagerank": A.pagerank, "sssp": lambda: A.sssp(0), "cc": A.cc}


def _close(a, b, **kw):
    return np.allclose(np.minimum(a, 1e18), np.minimum(b, 1e18), **kw)


def _mutated(g, batches, upto):
    s, d, w = G.edges_of(g)
    for b in batches[:upto]:
        s, d, w = apply_to_coo(s, d, w, g.n, b)
    return G.from_edges(g.n, s, d, w)


# -- DeltaBatch / generator --------------------------------------------------
def test_delta_batch_validation():
    with pytest.raises(ValueError):
        DeltaBatch(ins_src=[1, 2], ins_dst=[3], ins_w=[1.0, 1.0],
                   del_src=[], del_dst=[])
    with pytest.raises(ValueError):
        DeltaBatch(ins_src=[], ins_dst=[], ins_w=[],
                   del_src=[1], del_dst=[])
    b = DeltaBatch.of(ins=[(0, 1), (2, 3, 0.5)], dels=[(4, 5)])
    assert b.n_inserts == 2 and b.n_deletes == 1
    assert b.ins_w.dtype == np.float32 and b.ins_src.dtype == np.int64


def test_synthetic_stream_reproducible():
    g = G.powerlaw_graph(300, avg_deg=4, seed=0)
    a = synthetic_stream(g, 4, 50, seed=9, weighted=True)
    b = synthetic_stream(g, 4, 50, seed=9, weighted=True)
    assert len(a) == len(b) == 4
    for x, y in zip(a, b):
        for f in ("ins_src", "ins_dst", "ins_w", "del_src", "del_dst"):
            assert np.array_equal(getattr(x, f), getattr(y, f))
    # a different seed must differ somewhere
    c = synthetic_stream(g, 4, 50, seed=10, weighted=True)
    assert any(not np.array_equal(x.ins_dst, y.ins_dst)
               for x, y in zip(a, c))


def test_synthetic_stream_deletes_hit_live_edges():
    """Deletes are drawn from the tracked live multiset, so every delete
    pair must remove at least one edge when applied in sequence."""
    g = G.powerlaw_graph(200, avg_deg=4, seed=2)
    s, d, w = G.edges_of(g)
    for batch in synthetic_stream(g, 5, 40, seed=1, delete_frac=0.5):
        keys = set((s * g.n + d).tolist())
        for u, v in zip(batch.del_src, batch.del_dst):
            assert int(u) * g.n + int(v) in keys
        s, d, w = apply_to_coo(s, d, w, g.n, batch)


# -- incremental storage -----------------------------------------------------
def test_incremental_tiles_match_cold_storage():
    """After a mixed insert/delete stream, every block's live tile content
    equals (as a multiset) the cold-built storage of the mutated graph
    under the SAME epoch permutation — the incremental path loses and
    invents nothing."""
    g = G.powerlaw_graph(400, avg_deg=5, seed=4, weighted=True)
    se = StreamingEngine(g, A.pagerank(), CFG)
    batches = synthetic_stream(g, 3, 60, seed=5, delete_frac=0.3,
                               weighted=True)
    for batch in batches:
        se.ingest(batch)
    assert se.metrics.plan_rebuilds == 0  # else permutations differ
    plan = se.engine.plan
    ps, pd, w = se.store.live_base()
    gp = G.from_edges(g.n, ps, pd, w)  # permuted-space mutated graph
    cold = build_tiled_storage(gp, plan.block_size, plan.num_blocks)
    t = se.tiles
    for b in range(plan.num_blocks):
        lo = int(t.slot_lo[b])
        mark = slice(lo, lo + int(t.fill[b]))
        ok = t.valid[mark]  # in-place kills leave masked holes behind
        mine = sorted(zip(t.src[mark][ok], t.dstl[mark][ok],
                          np.round(t.w[mark][ok], 5)))
        c0 = int(cold.tile_start[b]) * cold.tile
        ref = slice(c0, c0 + int(cold.edges[b]))
        theirs = sorted(zip(cold.src.reshape(-1)[ref],
                            cold.dst_local.reshape(-1)[ref],
                            np.round(cold.w.reshape(-1)[ref], 5)))
        assert mine == theirs, f"block {b} diverged"
    assert np.array_equal(t.live, cold.edges)


def test_incremental_degrees_and_coupling_counts():
    g = G.powerlaw_graph(300, avg_deg=4, seed=6)
    se = StreamingEngine(g, A.cc(), CFG)  # symmetric: mirrors exercised
    for batch in synthetic_stream(g, 3, 50, seed=7, delete_frac=0.4):
        se.ingest(batch)
    plan = se.engine.plan
    g_int = G.symmetrize(se.current_graph())
    assert np.array_equal(se.out_deg, g_int.out_deg[plan.order])
    assert np.array_equal(se.in_deg, g_int.in_deg[plan.order])
    # W against a fresh O(m) count of the permuted internal graph
    inv = plan.inv
    s, d, _ = G.edges_of(g_int)
    c = plan.block_size
    w_ref = np.zeros_like(se.W)
    np.add.at(w_ref, (inv[s] // c, inv[d] // c), 1)
    assert np.array_equal(se.W, w_ref)


def test_append_in_place_keeps_epoch():
    """Small inserts go into the spare tile slots: no block rebuild, no
    plan rebuild, and the engine epoch (compiled fns) is preserved."""
    g = G.powerlaw_graph(400, avg_deg=5, seed=8)
    se = StreamingEngine(g, A.pagerank(), CFG)
    se.ingest(DeltaBatch.empty())  # warm the compile cache
    eng = se.engine
    rep = se.ingest(DeltaBatch.of(ins=[(1, 2), (3, 4), (5, 6)]))
    assert rep.appended_blocks > 0 and rep.rebuilt_blocks == 0
    assert not rep.plan_rebuild
    assert se.engine is eng  # same epoch, same compiled executables


def test_overflow_triggers_plan_rebuild():
    g = G.powerlaw_graph(300, avg_deg=4, seed=1)
    se = StreamingEngine(g, A.pagerank(), CFG,
                         StreamConfig(tile_slack=0.0, spare_tiles=0))
    hot = 7
    batch = DeltaBatch(ins_src=np.arange(250) % g.n,
                       ins_dst=np.full(250, hot),
                       ins_w=np.ones(250, np.float32),
                       del_src=[], del_dst=[])
    rep = se.ingest(batch)
    assert rep.plan_rebuild and se.metrics.plan_rebuilds == 1
    cold = StructureAwareEngine(_mutated(g, [batch], 1), A.pagerank(),
                                CFG).run()
    assert _close(se.values, cold.values, rtol=1e-4, atol=1e-5)


def test_edge_store_compaction_preserves_multiset():
    from repro.stream.apply import EdgeStore
    rng = np.random.default_rng(0)
    n, m = 64, 3000
    ps = rng.integers(0, n, m)
    pd = rng.integers(0, n, m)
    w = rng.random(m).astype(np.float32)
    store = EdgeStore(ps, pd, w, n, num_blocks=4, block_size=16,
                      symmetric=False)
    store.kill_pairs(ps[:2500], pd[:2500])
    assert store.n_live < m / 2
    before = sorted(zip(*(a.tolist() for a in store.live_base())))
    assert store.maybe_compact()
    assert store.m == store.n_live  # dead rows reclaimed
    after = sorted(zip(*(a.tolist() for a in store.live_base())))
    assert before == after
    got = sum(store.gather_block(b)[0].size for b in range(4))
    assert got == store.n_live


def test_empty_batch_is_noop():
    g = G.powerlaw_graph(200, avg_deg=4, seed=3)
    se = StreamingEngine(g, A.pagerank(), CFG)
    before = se.values.copy()
    rep = se.ingest(DeltaBatch.empty())
    assert rep.dirty_blocks == 0 and rep.iterations == 0
    assert np.array_equal(se.values, before)


def test_delta_ids_out_of_range_rejected():
    g = G.powerlaw_graph(100, avg_deg=3, seed=0)
    se = StreamingEngine(g, A.pagerank(), CFG)
    with pytest.raises(ValueError):
        se.ingest(DeltaBatch.of(ins=[(0, 100)]))
    with pytest.raises(ValueError):
        se.ingest(DeltaBatch.of(dels=[(-1, 0)]))


# -- the acceptance property -------------------------------------------------
@given(seed=st.integers(0, 20), n=st.integers(200, 600),
       algo=st.sampled_from(["pagerank", "sssp", "cc"]))
@settings(max_examples=6, deadline=None)
def test_stream_matches_cold_property(seed, n, algo):
    """After every DeltaBatch (inserts AND deletes), the warm incremental
    engine's values match a from-scratch StructureAwareEngine run on the
    mutated graph."""
    g = G.powerlaw_graph(n, avg_deg=4, seed=seed, weighted=True)
    mk = PROGS[algo]
    se = StreamingEngine(g, mk(), CFG)
    batches = synthetic_stream(g, 3, 40, seed=seed + 1, delete_frac=0.3,
                               weighted=True)
    for i, batch in enumerate(batches):
        se.ingest(batch)
        cold = StructureAwareEngine(_mutated(g, batches, i + 1), mk(),
                                    CFG).run()
        assert cold.metrics.converged
        assert _close(se.values, cold.values, rtol=1e-4, atol=1e-5), \
            f"{algo} diverged from cold run at batch {i}"


def test_delete_only_nonmonotone_reheat():
    """Deleting a chain's bridge edge must push everything behind it back
    to INF — the warm min-combine path can only do this through the
    reset_on_delete trimming (a plain warm restart would keep the stale
    finite distances forever)."""
    n = 64
    g = G.chain_graph(n, weighted=True)
    se = StreamingEngine(g, A.sssp(0), CFG)
    assert np.all(se.values[: n // 2] < 1e18)
    cut = n // 2
    rep = se.ingest(DeltaBatch.of(dels=[(cut - 1, cut)]))
    assert rep.vertices_reset >= n - cut
    cold = StructureAwareEngine(
        _mutated(g, [DeltaBatch.of(dels=[(cut - 1, cut)])], 1),
        A.sssp(0), CFG).run()
    assert _close(se.values, cold.values, rtol=1e-5, atol=1e-5)
    assert np.all(se.values[cut:] >= 1e18)  # unreachable again
    assert np.all(se.values[:cut] < 1e18)  # prefix untouched


def test_cc_delete_splits_component():
    """Deleting the only bridge between two halves must split the
    component labels again (max-propagation cannot lower labels without
    the reset path)."""
    # two cliques 0-3 and 4-7 joined by a single bridge 3->4
    ins = [(i, j) for i in range(4) for j in range(4) if i != j]
    ins += [(i, j) for i in range(4, 8) for j in range(4, 8) if i != j]
    src = np.array([e[0] for e in ins] + [3])
    dst = np.array([e[1] for e in ins] + [4])
    g = G.from_edges(8, src, dst)
    se = StreamingEngine(g, A.cc(), CFG)
    assert len(np.unique(se.values)) == 1  # one component via the bridge
    se.ingest(DeltaBatch.of(dels=[(3, 4)]))
    assert len(np.unique(se.values)) == 2
    cold = StructureAwareEngine(
        _mutated(g, [DeltaBatch.of(dels=[(3, 4)])], 1), A.cc(), CFG).run()
    assert _close(se.values, cold.values, atol=1e-6)


# -- sub-O(m) ingest: uploads, compaction ordering, delete semantics ---------
def test_upload_bytes_scale_with_touched_blocks():
    """Tentpole: a small batch's host->device payload covers the touched
    tile rows (plus changed aux entries / coupling rows / warm values),
    never the full edge arrays."""
    g = G.powerlaw_graph(6000, avg_deg=8, seed=3, weighted=True)
    se = StreamingEngine(g, A.pagerank(), CFG)
    s, d, _ = G.edges_of(g)
    batch = DeltaBatch.of(ins=[(0, 1), (17, 33)],
                          dels=[(int(s[0]), int(d[0]))])
    rep = se.ingest(batch)
    assert not rep.plan_rebuild
    assert 0 < rep.bytes_uploaded < 0.25 * rep.bytes_full
    assert rep.upload_frac < 0.25
    assert se.metrics.bytes_uploaded == rep.bytes_uploaded
    assert se.metrics.bytes_full == rep.bytes_full


def test_aux_change_rearms_without_reheat():
    """An insert changes its source's out-degree, which silently changes
    the aggregates of the source's OTHER out-neighbour blocks. Those
    blocks are re-armed with a finite PSD bump (aux_bumped_blocks) and
    still reconverge to the cold fixpoint — but only blocks whose storage
    actually moved count as dirty re-heat."""
    g = G.powerlaw_graph(3000, avg_deg=6, seed=5, weighted=True)
    se = StreamingEngine(g, A.pagerank(), CFG)
    s, _, _ = G.edges_of(g)
    u = int(np.argmax(np.bincount(s, minlength=g.n)))  # heavy out-degree
    batch = DeltaBatch.of(ins=[(u, (u + 1) % g.n)])
    rep = se.ingest(batch)
    assert rep.dirty_blocks <= 2  # the receiving block, not the fan-out
    assert rep.aux_bumped_blocks > 0
    cold = StructureAwareEngine(_mutated(g, [batch], 1), A.pagerank(),
                                CFG).run()
    assert _close(se.values, cold.values, rtol=1e-4, atol=1e-5)


def test_compaction_same_batch_as_deletes():
    """EdgeStore compaction fires at the END of an ingest whose deletes
    leave dead rows in the majority — in the same batch as the deletes,
    after every use of the batch's edge ids — and the incremental state
    stays equal to the cold truth through it and past it."""
    g = G.powerlaw_graph(400, avg_deg=8, seed=11, weighted=True)
    se = StreamingEngine(g, A.pagerank(), CFG)
    s, d, _ = G.edges_of(g)
    keys = np.unique(s * g.n + d)
    kill = keys[:int(keys.size * 0.7)]
    batches = [DeltaBatch(ins_src=[1, 2, 3], ins_dst=[4, 5, 6],
                          ins_w=np.ones(3, np.float32),
                          del_src=kill // g.n, del_dst=kill % g.n),
               DeltaBatch.of(ins=[(7, 8), (9, 10)], dels=[(1, 4)])]
    m_before = se.store.m
    rep = se.ingest(batches[0])
    assert rep.deletes >= kill.size
    assert se.store.m == se.store.n_live  # compacted in the delete batch
    assert se.store.m < m_before
    se.ingest(batches[1])  # ids from the compacted store still line up
    cold = StructureAwareEngine(_mutated(g, batches, 2), A.pagerank(),
                                CFG).run()
    assert _close(se.values, cold.values, rtol=1e-4, atol=1e-5)


def test_multi_copy_delete_kills_all_copies():
    """Pair-granular delete semantics, pinned: one delete of a duplicated
    (src, dst) pair removes EVERY live parallel copy — exactly what the
    apply_to_coo cold truth does — including copies inserted through the
    streaming path itself."""
    n = 64
    src = np.concatenate([np.arange(n - 1), [5, 5]])  # chain + 2 dup copies
    dst = np.concatenate([np.arange(1, n), [6, 6]])  # of the (5, 6) edge
    g = G.from_edges(n, src, dst)
    se = StreamingEngine(g, A.pagerank(), CFG)
    batch = DeltaBatch.of(dels=[(5, 6)])
    rep = se.ingest(batch)
    assert rep.deletes == 3
    cs, cd, _ = G.edges_of(se.current_graph())
    assert not np.any((cs == 5) & (cd == 6))
    cold = StructureAwareEngine(_mutated(g, [batch], 1), A.pagerank(),
                                CFG).run()
    assert _close(se.values, cold.values, rtol=1e-4, atol=1e-5)
    # fresh duplicates inserted incrementally die together the same way
    se.ingest(DeltaBatch.of(ins=[(5, 6), (5, 6)]))
    rep = se.ingest(DeltaBatch.of(dels=[(5, 6)]))
    assert rep.deletes == 2


def test_plan_rebuild_excluded_from_dirty_frac():
    """An overflow batch re-heats everything by construction; it must not
    inflate the in-place dirty average (satellite of the honest-metrics
    fix): StreamMetrics tracks it via plan_rebuilds instead."""
    g = G.powerlaw_graph(300, avg_deg=4, seed=1)
    se = StreamingEngine(g, A.pagerank(), CFG,
                         StreamConfig(tile_slack=0.0, spare_tiles=0))
    batch = DeltaBatch(ins_src=np.arange(250) % g.n,
                       ins_dst=np.full(250, 7),
                       ins_w=np.ones(250, np.float32),
                       del_src=[], del_dst=[])
    rep = se.ingest(batch)
    assert rep.plan_rebuild and rep.dirty_frac == 1.0
    assert rep.upload_frac == 1.0  # full re-upload, honestly billed
    m = se.metrics
    assert m.plan_rebuilds == 1
    assert m.dirty_blocks == 0 and m.blocks_seen == 0
    rep2 = se.ingest(DeltaBatch.of(ins=[(0, 1)]))
    assert not rep2.plan_rebuild
    assert m.blocks_seen == rep2.num_blocks
    assert m.dirty_blocks == rep2.dirty_blocks


def test_edge_store_successors_match_csr():
    """The EdgeStore-served out-edge oracle (reset_on_delete_frontier's
    backend) agrees with the cold CSR oracle on the mutated graph."""
    from repro.core.algorithms import graph_successors
    g = G.powerlaw_graph(400, avg_deg=5, seed=9, weighted=True)
    se = StreamingEngine(g, A.sssp(0), CFG)
    batch = synthetic_stream(g, 1, 50, seed=2, delete_frac=0.4,
                             weighted=True)[0]
    se.ingest(batch)
    succ_g = graph_successors(se.current_graph())
    rng = np.random.default_rng(0)
    def norm(tri):
        return sorted(zip(tri[0].tolist(), tri[1].tolist(),
                          np.round(np.asarray(tri[2], np.float64),
                                   5).tolist()))

    for _ in range(5):
        frontier = np.unique(rng.integers(0, g.n, 20))
        assert norm(se._successors(frontier)) == norm(succ_g(frontier))


@given(seed=st.integers(0, 30), symmetric=st.booleans())
@settings(max_examples=8, deadline=None)
def test_edge_store_invariants_under_churn(seed, symmetric):
    """EdgeStore invariants under random insert/delete/compact churn: the
    buckets always equal a fresh rebucketing of the live rows, n_live
    matches the alive mask, and gather_block matches a brute-force filter
    (base + mirror rows for symmetric stores)."""
    from repro.stream.apply import EdgeStore
    rng = np.random.default_rng(seed)
    n, nb, c = 96, 6, 16
    m0 = int(rng.integers(50, 1500))
    store = EdgeStore(rng.integers(0, n, m0), rng.integers(0, n, m0),
                      rng.random(m0).astype(np.float32), n, num_blocks=nb,
                      block_size=c, symmetric=symmetric)
    for _ in range(6):
        op = int(rng.integers(3))
        if op == 0:
            k = int(rng.integers(1, 120))
            store.insert(rng.integers(0, n, k), rng.integers(0, n, k),
                         rng.random(k).astype(np.float32))
        elif op == 1 and store.n_live:
            live = np.flatnonzero(store.alive[:store.m])
            pick = live[rng.integers(0, live.size,
                                     min(40, live.size))]
            store.kill_pairs(store.psrc[pick], store.pdst[pick])
        else:
            store.maybe_compact()

        assert store.n_live == int(store.alive[:store.m].sum())
        alive = store.alive[:store.m]
        for b in range(nb):
            for buckets, key in ((store.by_dst, store.pdst),
                                 (store.by_src, store.psrc)):
                ids = buckets[b]
                ids = ids[store.alive[ids]]
                ref = np.flatnonzero(alive & (key[:store.m] // c == b))
                assert set(ids.tolist()) == set(ref.tolist())
            esrc, edstl, ew = store.gather_block(b)
            got = sorted(zip(esrc.tolist(), edstl.tolist(),
                             np.round(ew, 5).tolist()))
            ref = np.flatnonzero(alive & (store.pdst[:store.m] // c == b))
            exp = list(zip(store.psrc[ref], store.pdst[ref] - b * c,
                           np.round(store.w[ref], 5)))
            if symmetric:
                mref = np.flatnonzero(alive
                                      & (store.psrc[:store.m] // c == b))
                exp += list(zip(store.pdst[mref],
                                store.psrc[mref] - b * c,
                                np.round(store.w[mref], 5)))
            exp = sorted((int(a), int(dl), float(ww)) for a, dl, ww in exp)
            assert [(int(a), int(dl), float(ww)) for a, dl, ww in got] == exp


def test_stream_metrics_accumulate():
    g = G.powerlaw_graph(300, avg_deg=4, seed=2)
    se = StreamingEngine(g, A.pagerank(), CFG)
    batches = synthetic_stream(g, 3, 30, seed=4)
    for b in batches:
        se.ingest(b)
    m = se.metrics
    assert m.batches == 3
    assert 0 < m.dirty_frac <= 1.0
    assert m.edges_reprocessed > 0 and m.iterations > 0
    assert m.edges_inserted == sum(b.n_inserts for b in batches)
    d = m.as_dict()
    assert d["batches"] == 3 and "latency_per_batch_s" in d


def test_adaptive_warm_matches_dense_and_host():
    """Satellite property (warm half): after delta batches WITH deletes,
    the adaptive warm path, the dense (adaptive=False) warm path, and a
    host-reference cold run on the mutated graph land on the same
    fixpoint for a sum program and a min program."""
    import dataclasses
    g = G.powerlaw_graph(500, avg_deg=4, seed=7, weighted=True)
    batches = synthetic_stream(g, 2, 40, seed=8, delete_frac=0.3,
                               weighted=True)
    for mk in (A.pagerank, lambda: A.sssp(0), A.cc):
        sa = StreamingEngine(g, mk(), CFG)
        sd = StreamingEngine(g, mk(),
                             dataclasses.replace(CFG, adaptive=False))
        for b in batches:
            sa.ingest(b)
            sd.ingest(b)
        host = StructureAwareEngine(_mutated(g, batches, 2), mk(),
                                    CFG).run(fused=False)
        assert host.metrics.converged
        assert _close(sa.values, sd.values, rtol=1e-4, atol=1e-5)
        assert _close(sa.values, host.values, rtol=1e-4, atol=1e-5)


def test_adaptive_warm_narrow_dispatch():
    """Delta-proportional warm restart: a tiny batch on a many-block graph
    reconverges in a narrow dispatch bucket (mean width < configured
    width), ends with most blocks retired, and reports the depth
    histogram — the auditable face of 'effort scales with the batch'.
    The insert joins two zero-degree vertices so the perturbation (dirty
    block + aux fan-out) stays small by construction."""
    g = G.powerlaw_graph(6000, avg_deg=6, seed=3, weighted=True)
    cfg = EngineConfig(t2=1e-9, width=8, block_size=128)
    se = StreamingEngine(g, A.pagerank(), cfg)
    assert se.engine.plan.num_blocks > 2 * cfg.width
    s, _, _ = G.edges_of(g)
    u, v = (int(x) for x in
            np.argsort(np.bincount(s, minlength=g.n))[:2])
    batch = DeltaBatch.of(ins=[(u, v)])
    rep = se.ingest(batch)
    assert rep.iterations > 0
    assert 0 < rep.mean_dispatch_width < cfg.width
    assert rep.blocks_retired > rep.num_blocks // 2
    assert sum(rep.inner_depth_hist.values()) > 0
    m = se.metrics
    assert m.mean_dispatch_width == pytest.approx(rep.mean_dispatch_width)
    assert m.blocks_retired == rep.blocks_retired
    assert "mean_dispatch_width" in m.as_dict()
    # and the narrow schedule still reaches the cold fixpoint
    cold = StructureAwareEngine(_mutated(g, [batch], 1),
                                A.pagerank(), cfg).run()
    assert _close(se.values, cold.values, rtol=1e-4, atol=1e-5)


def test_warm_processes_fewer_edges_than_cold_mode():
    """The headline: reconverging from the warm state through re-heated
    dirty blocks does strictly less edge work than a cold recompute of
    the same mutated graph on the same engine."""
    g = G.core_periphery_graph(4000, avg_deg=6, seed=1, chords=1)
    batches = synthetic_stream(g, 2, 60, seed=2)
    warm = StreamingEngine(g, A.pagerank(), CFG)
    cold = StreamingEngine(g, A.pagerank(), CFG, StreamConfig(warm=False))
    warm_edges = cold_edges = 0
    for b in batches:
        warm_edges += warm.ingest(b).edges_processed
        cold_edges += cold.ingest(b).edges_processed
    assert _close(warm.values, cold.values, rtol=1e-4, atol=1e-5)
    assert warm_edges < cold_edges
