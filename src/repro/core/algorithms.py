"""Vertex programs: PR, CC, SSSP, BFS (+ BC driver in ``engine.bc``).

Each program supplies the pull-mode update and its *state degree* delta
(paper §3.3): PR uses Eq. 3 (|rank_curr - rank_next| accumulation), SSSP uses
Eq. 4 (the smaller of the two results, accumulated on change), CC the
max-analogue the paper describes.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph

INF = np.float32(1e18)  # finite 'infinity': keeps inf-inf NaNs out of f32 math

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class VertexProgram:
    name: str
    combine: str  # 'sum' | 'min' | 'max'
    needs_symmetric: bool
    monotone_cooling: bool  # True -> barrier repartitioning is sound (PR-like)
    damping: float = 0.85
    # init(graph) -> (values (n,), aux (n,)); aux is per-vertex constant data
    init: Callable[[Graph], tuple[np.ndarray, np.ndarray]] = None
    # edge_map(src_val, src_aux, w) -> message
    edge_map: Callable[[Array, Array, Array], Array] = None
    # apply(old_block, agg_block, n_total) -> new_block
    apply: Callable[[Array, Array, int], Array] = None
    # sd_delta(old_block, new_block) -> nonnegative activity contribution
    sd_delta: Callable[[Array, Array], Array] = None

    @property
    def identity(self) -> np.float32:
        return {"sum": np.float32(0.0), "min": INF,
                "max": np.float32(-INF)}[self.combine]


def pagerank(damping: float = 0.85) -> VertexProgram:
    def init(g: Graph):
        vals = np.full(g.n, 1.0 / g.n, dtype=np.float32)
        aux = np.maximum(g.out_deg, 1).astype(np.float32)
        return vals, aux

    def edge_map(src_val, src_aux, w):
        del w
        return src_val / src_aux

    def apply(old, agg, n_total):
        del old
        return (1.0 - damping) / n_total + damping * agg

    def sd_delta(old, new):  # Eq. 3
        return jnp.abs(new - old)

    return VertexProgram(name="pagerank", combine="sum", needs_symmetric=False,
                         monotone_cooling=True, damping=damping, init=init,
                         edge_map=edge_map, apply=apply, sd_delta=sd_delta)


def sssp(source: int = 0) -> VertexProgram:
    def init(g: Graph):
        vals = np.full(g.n, INF, dtype=np.float32)
        vals[source] = 0.0
        return vals, np.zeros(g.n, dtype=np.float32)

    def edge_map(src_val, src_aux, w):
        del src_aux
        return src_val + w

    def apply(old, agg, n_total):
        del n_total
        return jnp.minimum(old, agg)

    def sd_delta(old, new):  # Eq. 4: min of the two results, on change
        return jnp.where(new < old, jnp.minimum(new, old), 0.0)

    return VertexProgram(name="sssp", combine="min", needs_symmetric=False,
                         monotone_cooling=False, init=init, edge_map=edge_map,
                         apply=apply, sd_delta=sd_delta)


def bfs(source: int = 0) -> VertexProgram:
    def init(g: Graph):
        vals = np.full(g.n, INF, dtype=np.float32)
        vals[source] = 0.0
        return vals, np.zeros(g.n, dtype=np.float32)

    def edge_map(src_val, src_aux, w):
        del src_aux, w
        return src_val + 1.0

    def apply(old, agg, n_total):
        del n_total
        return jnp.minimum(old, agg)

    def sd_delta(old, new):
        return jnp.where(new < old, 1.0, 0.0)

    return VertexProgram(name="bfs", combine="min", needs_symmetric=False,
                         monotone_cooling=False, init=init, edge_map=edge_map,
                         apply=apply, sd_delta=sd_delta)


def cc() -> VertexProgram:
    """Connected components via max-label propagation (paper: 'take a
    maximum'); requires the symmetrized graph."""

    def init(g: Graph):
        return np.arange(g.n, dtype=np.float32), np.zeros(g.n, np.float32)

    def edge_map(src_val, src_aux, w):
        del src_aux, w
        return src_val

    def apply(old, agg, n_total):
        del n_total
        return jnp.maximum(old, agg)

    def sd_delta(old, new):  # the larger of the two results, on change
        return jnp.where(new > old, jnp.maximum(new, old), 0.0)

    return VertexProgram(name="cc", combine="max", needs_symmetric=True,
                         monotone_cooling=False, init=init, edge_map=edge_map,
                         apply=apply, sd_delta=sd_delta)


REGISTRY: dict[str, Callable[..., VertexProgram]] = {
    "pagerank": pagerank,
    "sssp": sssp,
    "bfs": bfs,
    "cc": cc,
}
