"""Pallas TPU kernel: edge-block segment-sum (the engine's hot spot).

TPU adaptation of the paper's cache-block processing (DESIGN.md §2): a
partition's edge slice is streamed HBM->VMEM in tiles of ``tile_e`` edges;
the scatter-style segment reduction is re-expressed as a one-hot matmul so
it runs on the MXU (systolic array) instead of a serial scatter unit:

    out[c] = sum_e msg[e] * [dst[e] == c]   ==   (1, E_t) @ (E_t, C)

Block shapes: tile_e x C one-hot in f32 (512 x 512 -> 1 MiB VMEM), MXU-
aligned (multiples of 128 on both contraction and output dims). The output
block is revisited by every grid step (accumulator-in-VMEM pattern): zeroed
at step 0, flushed once at the end — HBM traffic is exactly E reads +
C writes, the roofline minimum for this op.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(msg_ref, dst_ref, out_ref, *, tile_e: int, block_c: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    msg = msg_ref[...].astype(jnp.float32)  # (1, tile_e)
    dst = dst_ref[...]  # (1, tile_e) int32
    # one-hot on the MXU contraction dim: (tile_e, block_c)
    cols = jax.lax.broadcasted_iota(jnp.int32, (tile_e, block_c), 1)
    onehot = (dst.reshape(tile_e, 1) == cols).astype(jnp.float32)
    out_ref[...] += jnp.dot(msg, onehot,
                            preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_size", "tile_e",
                                             "interpret"))
def edge_block_sum(msg: jnp.ndarray, dst: jnp.ndarray, block_size: int,
                   tile_e: int = 512, interpret: bool = True) -> jnp.ndarray:
    """Segment-sum ``msg`` into ``block_size`` slots addressed by ``dst``.

    msg: (E,) float; dst: (E,) int32 in [0, block_size). E is padded to a
    multiple of tile_e (pad messages are 0 so slot 0 is unaffected).
    """
    e = msg.shape[0]
    pad = (-e) % tile_e
    if pad:
        msg = jnp.pad(msg, (0, pad))
        dst = jnp.pad(dst, (0, pad))
    e_pad = e + pad
    grid = (e_pad // tile_e,)
    out = pl.pallas_call(
        functools.partial(_kernel, tile_e=tile_e, block_c=block_size),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_e), lambda i: (0, i)),
            pl.BlockSpec((1, tile_e), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, block_size), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, block_size), jnp.float32),
        interpret=interpret,
    )(msg.reshape(1, e_pad).astype(jnp.float32),
      dst.reshape(1, e_pad).astype(jnp.int32))
    return out.reshape(block_size).astype(msg.dtype)
