"""Incremental mutation of the engine's edge state.

Two host-side structures cooperate, both living in the PERMUTED vertex
space of the current engine epoch:

  * :class:`EdgeStore` — the growable COO multiset of the BASE graph (the
    truth), bucketed per destination AND per source block so a dirty
    block's in-edge list (plus its mirror rows under symmetrization, and
    the out-neighbour lookup behind aux-dirty marking) can be re-gathered
    without a global sort or scan. Deletes are lazy (an alive mask);
    buckets compact opportunistically on gather, and the arrays themselves
    compact between batches once dead rows outnumber live ones.
  * :class:`MutableTiledState` — the mutable mirror of the engine's
    slack-padded :class:`TiledStorage`. A small insert APPENDS at a block's
    watermark into the spare invalid slots; a delete KILLS its slots in
    place (masked holes, no data movement); a block whose watermark hits
    capacity is REBUILT (= compacted) from the EdgeStore truth — per-block,
    vectorised, never a global rebuild. Every mutation records the tile
    rows it touched, so the device commit uploads exactly those rows
    (``StructureAwareEngine.update_edge_rows``) instead of the full
    arrays. Only when a rebuild itself overflows a block's build-time
    capacity does the caller fall back to a full plan rebuild.

Symmetrized programs (CC) never match mirrored edge copies individually —
a mirror slot of (s, d) is signature-identical to a base slot of (d, s),
so in-place kills would be ambiguous. Any block whose base or mirror
in-edges could change is instead rebuilt from the base truth (base rows by
dst-bucket + mirrored rows by src-bucket), which makes the incremental
state equal ``symmetrize(mutated base)`` by construction.
"""
from __future__ import annotations

import numpy as np

from repro.core.partition import TiledStorage


class EdgeStore:
    """Growable base-graph COO multiset in permuted ids + block buckets."""

    def __init__(self, psrc: np.ndarray, pdst: np.ndarray, w: np.ndarray,
                 n: int, num_blocks: int, block_size: int, symmetric: bool):
        m0 = int(psrc.size)
        cap = max(2 * m0, 1024)
        self.n = n
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.symmetric = symmetric
        self.psrc = np.zeros(cap, dtype=np.int64)
        self.pdst = np.zeros(cap, dtype=np.int64)
        self.w = np.zeros(cap, dtype=np.float32)
        self.alive = np.zeros(cap, dtype=bool)
        self.psrc[:m0] = psrc
        self.pdst[:m0] = pdst
        self.w[:m0] = w
        self.alive[:m0] = True
        self.m = m0  # high-water mark
        self.n_live = m0
        self.by_dst = self._bucket(self.pdst[:m0])
        # by-src buckets serve the symmetric mirror gather AND the
        # aux-dirty out-neighbour lookup, so they are always maintained
        self.by_src = self._bucket(self.psrc[:m0])

    def _bucket(self, keys: np.ndarray) -> list[np.ndarray]:
        order = np.argsort(keys // self.block_size, kind="stable")
        bounds = np.searchsorted(keys[order] // self.block_size,
                                 np.arange(self.num_blocks + 1))
        return [order[bounds[b]:bounds[b + 1]].astype(np.int64)
                for b in range(self.num_blocks)]

    def _grow(self, need: int) -> None:
        cap = self.psrc.size
        if self.m + need <= cap:
            return
        new_cap = max(2 * cap, self.m + need)
        for name in ("psrc", "pdst", "w", "alive"):
            a = getattr(self, name)
            b = np.zeros(new_cap, dtype=a.dtype)
            b[:self.m] = a[:self.m]
            setattr(self, name, b)

    def _bucket_live(self, buckets: list[np.ndarray],
                     b: int) -> np.ndarray:
        """Live ids of one bucket, compacting it in passing."""
        ids = buckets[b]
        ids = ids[self.alive[ids]]
        buckets[b] = ids
        return ids

    def kill_pairs(self, kpsrc: np.ndarray,
                   kpdst: np.ndarray) -> np.ndarray:
        """Mark ALL live copies of the given (src, dst) pairs dead; returns
        the killed copy ids (for degree / coupling / reset bookkeeping).

        Pair-granular BY DESIGN, not by accident: :class:`DeltaBatch`
        deletes are (src, dst) pairs and the cold oracle
        (``delta.apply_to_coo``) drops every parallel copy of a deleted
        pair, so killing all live copies here is exactly what keeps the
        incremental multiset equal to the cold truth (pinned by
        tests/test_stream.py::test_multi_copy_delete_kills_all_copies).
        Only the dst-buckets of the deleted pairs are scanned — O(edges of
        the touched blocks), not O(m)."""
        if kpsrc.size == 0 or self.m == 0:
            return np.empty(0, dtype=np.int64)
        dkeys = np.unique(kpsrc * self.n + kpdst)
        cand = [self._bucket_live(self.by_dst, int(b))
                for b in np.unique(kpdst // self.block_size)]
        cand = (np.concatenate(cand) if cand
                else np.empty(0, dtype=np.int64))
        keys = self.psrc[cand] * self.n + self.pdst[cand]
        ids = cand[np.isin(keys, dkeys)]
        self.alive[ids] = False
        self.n_live -= ids.size
        return ids

    def maybe_compact(self, max_dead_frac: float = 0.5) -> bool:
        """Reclaim dead rows once they outnumber the live ones: a
        long-lived engine under steady insert/delete churn must not grow
        its arrays (and its scan costs) without bound. Invalidates all
        previously-returned ids — the streaming engine calls this at the
        very END of ``ingest``, after every use of the batch's killed /
        inserted ids (degree bumps, tile kills, gather-based rebuilds,
        reset bookkeeping) has completed."""
        dead = self.m - self.n_live
        if self.m < 1024 or dead <= self.n_live * max_dead_frac:
            return False
        live = np.flatnonzero(self.alive[:self.m])
        k = live.size
        for name in ("psrc", "pdst", "w"):
            a = getattr(self, name)
            a[:k] = a[live]
        self.alive[:k] = True
        self.alive[k:self.m] = False
        self.m = k
        self.by_dst = self._bucket(self.pdst[:k])
        self.by_src = self._bucket(self.psrc[:k])
        return True

    def insert(self, ipsrc: np.ndarray, ipdst: np.ndarray,
               iw: np.ndarray) -> np.ndarray:
        """Append insert copies; returns their ids."""
        k = int(ipsrc.size)
        if k == 0:
            return np.empty(0, dtype=np.int64)
        self._grow(k)
        ids = np.arange(self.m, self.m + k, dtype=np.int64)
        self.psrc[ids] = ipsrc
        self.pdst[ids] = ipdst
        self.w[ids] = iw
        self.alive[ids] = True
        self.m += k
        self.n_live += k
        for buckets, keys in ((self.by_dst, ipdst),
                              (self.by_src, ipsrc)):
            kb = keys // self.block_size
            for b in np.unique(kb):
                buckets[int(b)] = np.concatenate(
                    [buckets[int(b)], ids[kb == b]])
        return ids

    def gather_block(self, b: int) -> tuple[np.ndarray, np.ndarray,
                                            np.ndarray]:
        """All live in-edges of block b as (src, dst_local, w) — base rows
        plus mirrored rows for symmetric engines. Compacts the buckets."""
        lo = b * self.block_size
        ids = self._bucket_live(self.by_dst, b)
        esrc, edst, ew = self.psrc[ids], self.pdst[ids], self.w[ids]
        if self.symmetric:
            mid = self._bucket_live(self.by_src, b)
            esrc = np.concatenate([esrc, self.pdst[mid]])
            edst = np.concatenate([edst, self.psrc[mid]])
            ew = np.concatenate([ew, self.w[mid]])
        return (esrc.astype(np.int32), (edst - lo).astype(np.int32), ew)

    def out_blocks_of(self, vertices: np.ndarray) -> np.ndarray:
        """Destination blocks of the live INTERNAL out-edges of the given
        vertices — the blocks whose aggregates silently change when those
        sources' aux (e.g. out-degree) changes. Same bucket scan as
        :meth:`successors`, reduced to distinct destination blocks."""
        return np.unique(self.successors(vertices)[1] // self.block_size)

    def successors(self, vertices: np.ndarray) -> tuple[np.ndarray,
                                                        np.ndarray,
                                                        np.ndarray]:
        """Live INTERNAL out-edges of the given (permuted) vertices as
        (src, dst, w) triples — the frontier-closure oracle behind
        ``reset_on_delete_frontier``. Served from the by-src buckets (plus
        reversed base in-edges when symmetric): the per-hop cost is the
        edges of the frontier's own blocks, and no O(m) CSR is ever
        rebuilt per delete batch."""
        vertices = np.asarray(vertices, dtype=np.int64)
        e64, ef = np.empty(0, dtype=np.int64), np.empty(0, dtype=np.float32)
        if vertices.size == 0 or self.m == 0:
            return e64, e64, ef
        srcs: list[np.ndarray] = []
        dsts: list[np.ndarray] = []
        ws: list[np.ndarray] = []
        for b in np.unique(vertices // self.block_size):
            ids = self._bucket_live(self.by_src, int(b))
            sel = ids[np.isin(self.psrc[ids], vertices)]
            if sel.size:
                srcs.append(self.psrc[sel])
                dsts.append(self.pdst[sel])
                ws.append(self.w[sel])
            if self.symmetric:
                # mirrored out-edges of v are its reversed base in-edges
                mid = self._bucket_live(self.by_dst, int(b))
                msel = mid[np.isin(self.pdst[mid], vertices)]
                if msel.size:
                    srcs.append(self.pdst[msel])
                    dsts.append(self.psrc[msel])
                    ws.append(self.w[msel])
        if not srcs:
            return e64, e64, ef
        return (np.concatenate(srcs), np.concatenate(dsts),
                np.concatenate(ws))

    def out_block_mass(self, vertices: np.ndarray, mass: np.ndarray,
                       subblocks: int = 1) -> np.ndarray:
        """(num_blocks,) per-destination-block sum of ``mass[i]`` over the
        live internal out-edges of ``vertices[i]`` — the data behind the
        aux staleness bump: when a source's aux changes, the bound on the
        message-delta mass entering each downstream block. With
        ``subblocks`` the sum is resolved per destination sub-range —
        (num_blocks, S) — at the same bucket-scan cost (the destination
        id is already in hand). Scans only the src-buckets of the
        vertices' own blocks, not the whole edge set."""
        shape = (self.num_blocks if subblocks == 1
                 else (self.num_blocks, subblocks))
        out = np.zeros(shape, dtype=np.float64)
        vertices = np.asarray(vertices, dtype=np.int64)
        if vertices.size == 0 or self.m == 0:
            return out
        order = np.argsort(vertices, kind="stable")
        sv, sm = vertices[order], np.asarray(mass, np.float64)[order]
        c = self.block_size
        ksub = c // max(subblocks, 1)

        def add(ids: np.ndarray, key: np.ndarray, tgt: np.ndarray) -> None:
            pos = np.minimum(np.searchsorted(sv, key[ids]), sv.size - 1)
            hit = sv[pos] == key[ids]
            if hit.any():
                t = tgt[ids[hit]]
                at = (t // c if subblocks == 1
                      else (t // c, (t % c) // ksub))
                np.add.at(out, at, sm[pos[hit]])

        for b in np.unique(vertices // c):
            add(self._bucket_live(self.by_src, int(b)), self.psrc,
                self.pdst)
            if self.symmetric:
                add(self._bucket_live(self.by_dst, int(b)), self.pdst,
                    self.psrc)
        return out

    def live_base(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """The live base multiset (permuted ids)."""
        live = self.alive[:self.m]
        return (self.psrc[:self.m][live], self.pdst[:self.m][live],
                self.w[:self.m][live])


class MutableTiledState:
    """Mutable host mirror of one epoch's slack-padded TiledStorage.

    Invariant: block b's live edges occupy VALID slots inside the watermark
    prefix ``[slot_lo[b], slot_lo[b] + fill[b])`` of its flattened tile run
    ``[slot_lo[b], slot_lo[b] + cap[b])``; everything past the watermark is
    masked invalid. In-place kills leave masked holes behind (``live[b]``
    <= ``fill[b]``), appends land at the watermark, and ``rebuild`` (also
    the compaction path when the watermark hits capacity while holes
    remain) squashes the run back to a dense prefix.

    Every mutation marks the tile rows it touched in ``row_dirty``;
    ``pop_dirty_rows`` drains them so the device commit scatters exactly
    the changed rows instead of re-uploading the whole arrays.
    """

    def __init__(self, store: TiledStorage):
        self.tile = store.tile
        self.num_blocks = store.num_blocks
        self.shape2d = store.src.shape
        self.src = store.src.reshape(-1).copy()
        self.dstl = store.dst_local.reshape(-1).copy()
        self.w = store.w.reshape(-1).copy()
        self.valid = store.valid.reshape(-1).copy()
        self.slot_lo = store.tile_start.astype(np.int64) * self.tile
        self.cap = store.tile_cnt.astype(np.int64) * self.tile
        self.fill = np.asarray(store.edges, dtype=np.int64).copy()
        self.live = self.fill.copy()  # valid slots per block (fill - holes)
        self.row_dirty = np.zeros(self.shape2d[0], dtype=bool)

    def _mark_rows(self, slot_lo: int, slot_hi: int) -> None:
        if slot_hi > slot_lo:
            self.row_dirty[slot_lo // self.tile:
                           -(-slot_hi // self.tile)] = True

    def pop_dirty_rows(self) -> np.ndarray:
        """Tile rows touched since the last drain (sorted, unique)."""
        rows = np.flatnonzero(self.row_dirty)
        self.row_dirty[rows] = False
        return rows

    def append(self, b: int, asrc: np.ndarray, adstl: np.ndarray,
               aw: np.ndarray) -> bool:
        """In-place append at block b's watermark; False when the watermark
        would pass capacity (caller then compacts via ``rebuild``)."""
        k = int(asrc.size)
        if self.fill[b] + k > self.cap[b]:
            return False
        lo = int(self.slot_lo[b] + self.fill[b])
        self.src[lo:lo + k] = asrc
        self.dstl[lo:lo + k] = adstl
        self.w[lo:lo + k] = aw
        self.valid[lo:lo + k] = True
        self.fill[b] += k
        self.live[b] += k
        self._mark_rows(lo, lo + k)
        return True

    def kill(self, b: int, ksrc: np.ndarray, kdstl: np.ndarray) -> int:
        """Invalidate every live slot of block b matching one of the given
        (src, dst_local) pairs — pair-granular, all parallel copies, no
        data movement; only the rows holding killed slots become dirty.
        NON-SYMMETRIC layouts only: a mirror slot of (s, d) is
        signature-identical to a base slot of (d, s), so symmetric callers
        must rebuild the block from truth instead."""
        lo, hi = int(self.slot_lo[b]), int(self.slot_lo[b] + self.fill[b])
        if ksrc.size == 0 or hi == lo:
            return 0
        sig = (self.src[lo:hi].astype(np.int64) << 32) | self.dstl[lo:hi]
        ksig = (np.asarray(ksrc, np.int64) << 32) | np.asarray(kdstl,
                                                              np.int64)
        hit = self.valid[lo:hi] & np.isin(sig, ksig)
        idx = lo + np.flatnonzero(hit)
        self.valid[idx] = False
        self.live[b] -= idx.size
        self.row_dirty[np.unique(idx // self.tile)] = True
        return int(idx.size)

    def rebuild(self, b: int, esrc: np.ndarray, edstl: np.ndarray,
                ew: np.ndarray) -> bool:
        """Rewrite block b's tile run from truth (squashing any holes);
        False on overflow of the run's build-time capacity. Only slots up
        to max(old watermark, k) can differ from the device copy — the
        slack beyond both was invalid on both sides all along — so only
        those rows are marked dirty."""
        k = int(esrc.size)
        if k > self.cap[b]:
            return False
        lo = int(self.slot_lo[b])
        hi = int(max(self.fill[b], k))
        self.src[lo:lo + k] = esrc
        self.dstl[lo:lo + k] = edstl
        self.w[lo:lo + k] = ew
        self.valid[lo:lo + k] = True
        self.valid[lo + k:lo + hi] = False
        self.fill[b] = k
        self.live[b] = k
        self._mark_rows(lo, lo + hi)
        return True

    def rows2d(self, rows: np.ndarray) -> dict:
        """Gathered (len(rows), TILE) payload of the given tile rows — the
        host->device scatter payload, O(touched rows), never O(n_tiles).

        Also the out-of-core tier's truth oracle: when the engine runs
        under a residency budget, ``SpillStore.row_source`` points here,
        so evicting a block never needs a device readback (this mirror IS
        the device rows by the commit invariant) and a demand fetch
        re-scatters from the same payload the streaming commit would."""
        return {"src": self.src.reshape(self.shape2d)[rows],
                "dst_local": self.dstl.reshape(self.shape2d)[rows],
                "w": self.w.reshape(self.shape2d)[rows],
                "valid": self.valid.reshape(self.shape2d)[rows]}
