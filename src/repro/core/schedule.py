"""Adaptive partition scheduling (paper Alg. 3, §4).

Each iteration selects the m highest-PSD hot blocks; every I2-th iteration it
also admits the n highest-PSD cold blocks, with m + n = the worker count
(paper: the CPU count; here: the schedule width = devices on the data axis x
blocks per device) and m > n. When no hot blocks remain, the full width goes
to the highest-PSD cold blocks.

Two implementations of the same policy:

  * :meth:`Scheduler.select` — numpy, host-driven loop (reference);
  * :func:`make_device_select` — jnp, traced into the fused superstep so
    scheduling never leaves the device. Carries
    ``@decision_identical(twin=Scheduler.select)``
    (repro.analysis.contracts) — the normative statement that the two
    return the same blocks, same order, same tie-breaking — enforced by
    the static contract gate (``python -m repro.analysis``) on top of the
    shared property test
    (tests/test_engines.py::test_device_select_matches_numpy).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.analysis.contracts import decision_identical
from repro.core import state


@dataclasses.dataclass(frozen=True)
class Selection:
    hot_ids: np.ndarray  # (<=m,) global block ids scheduled in async mode
    cold_ids: np.ndarray  # (<=n or <=W,) block ids scheduled in sync mode


@dataclasses.dataclass
class Scheduler:
    """Host reference scheduler. ``width`` and ``i2`` are deliberately
    mutable: the adaptive engine retargets them at repartition boundaries
    (dispatch-width bucket) and per warm restart (delta-scaled cadence),
    mirroring what the fused path does with compiled buckets + a traced
    i2."""

    width: int  # W = m + n
    i2: int = 4  # cold-admission cadence
    cold_frac: float = 0.25  # n = floor(W * cold_frac) (m > n per the paper)
    min_psd: float = 0.0  # prune individually-converged blocks (see engine)

    def select(self, iteration: int, psd: np.ndarray,
               is_hot: np.ndarray) -> Selection:
        w = self.width
        # Hierarchical partitions: a (P, S) per-sub-block PSD folds to its
        # block priority (max over sub-blocks) — scheduling decisions stay
        # block-granular; the sub-block masks live inside the sweeps.
        psd = state.fold_subblock_psd(psd)
        live = psd >= self.min_psd  # safe: if ALL pruned, sum(psd) < T2
        hot_ids = np.flatnonzero(is_hot & live)
        cold_ids = np.flatnonzero(~is_hot & live)
        if hot_ids.size == 0:  # "only remains P_cold"
            pick = cold_ids[np.argsort(-psd[cold_ids], kind="stable")][:w]
            return Selection(hot_ids=np.empty(0, np.int64), cold_ids=pick)

        if self.i2 and iteration % self.i2 == 0:
            # I2 iteration: m hot + n cold (m > n), paper Alg. 3.
            n = int(w * self.cold_frac)
            m = w - n
        else:
            # non-I2 iteration: hot partitions have absolute priority...
            m, n = w, 0
        hot_pick = hot_ids[np.argsort(-psd[hot_ids], kind="stable")][:m]
        # ...but scheduling is work-conserving: idle workers (fewer live hot
        # blocks than m) take the next-hottest cold blocks instead of
        # idling — "ensure that the hot partition is sufficiently computed"
        # constrains priority, not utilization.
        n = w - hot_pick.size if hot_pick.size < m else n
        cold_pick = cold_ids[np.argsort(-psd[cold_ids], kind="stable")][:n]
        return Selection(hot_ids=hot_pick, cold_ids=cold_pick)


@decision_identical(twin=Scheduler.select)
def make_device_select(width: int, cold_frac: float,
                       min_psd: float, pad_id: int = 0):
    """jnp port of :meth:`Scheduler.select` for the fused superstep.

    Returns ``select(iteration, i2, psd, is_hot) -> (hot_rows, hot_ok,
    cold_rows, cold_ok)``: fixed-width (W,) block-id slots plus validity
    masks, where ``hot_rows[hot_ok]`` equals ``Selection.hot_ids`` (same
    blocks, same order) and likewise for cold. Tie-breaking matches the
    numpy version exactly: descending PSD, lowest block id first on equal
    PSD (a stable sort over ids in ascending order).

    ``width`` is static (it shapes the slot arrays — the adaptive engine
    compiles one select per dispatch-width bucket); ``i2`` is a TRACED
    argument so warm streaming restarts can scale the cold-admission
    cadence per batch without compiling a new superstep.

    ``pad_id`` fills slots beyond the take counts. Those slots are never
    marked ok, but the fused sweeps still *compute* them (discarding the
    result), so callers should pass their cheapest block id — padding with
    block 0 would bill every dead slot at the post-sort hub block's cost.
    """
    n_cold_quota = int(width * cold_frac)
    slots = jnp.arange(width)

    def select(iteration, i2, psd, is_hot):
        # Block priority = max over sub-blocks when psd carries a (P, S)
        # sub-block axis (identity at S = 1; see Scheduler.select).
        psd = state.fold_subblock_psd_device(psd)
        live = psd >= min_psd
        hot_live = is_hot & live
        cold_live = jnp.logical_not(is_hot) & live
        n_hot = hot_live.sum()
        n_cold = cold_live.sum()
        # Dead slots sink to -inf: a stable ascending argsort of the negated
        # key yields (psd desc, id asc) — identical to np.flatnonzero order
        # followed by a stable sort on -psd.
        hot_order = jnp.argsort(
            jnp.where(hot_live, -psd, jnp.inf), stable=True)
        cold_order = jnp.argsort(
            jnp.where(cold_live, -psd, jnp.inf), stable=True)
        is_i2 = (i2 > 0) & (iteration % jnp.maximum(i2, 1) == 0)
        m = jnp.where(is_i2, width - n_cold_quota, width)
        n = jnp.where(is_i2, n_cold_quota, 0)
        hot_take = jnp.minimum(m, n_hot)
        # work-conserving top-up (also covers the no-hot-blocks case:
        # hot_take == 0 < m hands the full width to cold)
        n = jnp.where(hot_take < m, width - hot_take, n)
        cold_take = jnp.minimum(n, n_cold)

        def to_slots(order, take):
            # slots beyond the take (and beyond P when P < width) carry
            # pad_id, not whatever pruned block the argsort left there
            k = min(width, order.shape[0])
            rows = jnp.full(width, pad_id, jnp.int32).at[:k].set(
                order[:k].astype(jnp.int32))
            return jnp.where(slots < take, rows, pad_id)

        return (to_slots(hot_order, hot_take), slots < hot_take,
                to_slots(cold_order, cold_take), slots < cold_take)

    return select


def schedule_predictor(width: int, i2: int, cold_frac: float,
                       min_psd: float) -> Scheduler:
    """The out-of-core paging tier's lookahead: a host Scheduler twin of
    the fused device select. Because the two implementations are kept
    decision-identical (the ``@decision_identical`` contract on
    :func:`make_device_select` is load-bearing here, not just a
    regression net), one numpy ``select`` call tells the spill tier
    exactly which
    blocks the imminent device superstep will read, BEFORE the device
    runs it. That is what lets ``repro.ooc.store.SpillStore`` page the
    demand set in ahead of the sweep without ever changing the schedule:
    a budget-constrained run stays bitwise-identical to the fully
    resident one. The engine retargets ``width`` at repartition
    boundaries (mutate ``.width`` — the cold quota is width-dependent,
    so the predictor must track the live dispatch bucket exactly)."""
    return Scheduler(width=width, i2=i2, cold_frac=cold_frac,
                     min_psd=min_psd)


# -- adaptive active-set helpers ---------------------------------------------
def width_ladder(width: int, min_width: int = 2) -> list[int]:
    """Descending dispatch-width buckets: the configured width, then powers
    of two below it down to ``min_width``. The fused engine compiles one
    superstep per bucket and the host picks the bucket matching the live
    active-set size at each repartition boundary, so tail supersteps stop
    paying full-width sweeps over padded slots."""
    ladder = [width]
    b = 1 << max(width.bit_length() - 1, 0)
    if b == width:
        b >>= 1
    while b >= max(min_width, 1):
        ladder.append(b)
        b >>= 1
    return ladder


def pick_width(ladder: list[int], active: int) -> int:
    """Smallest bucket that covers the active set (the widest bucket when
    none does). ``ladder`` is descending, as built by :func:`width_ladder`."""
    for wb in reversed(ladder):
        if wb >= active:
            return wb
    return ladder[0]


def admission_order(priority: np.ndarray) -> np.ndarray:
    """Lane-admission order for the query service: stable descending sort
    of per-query priorities, ties broken by submit order. The priority is
    the pinned epoch's activity of the query's seed vertices (paper Eq. 1,
    the same quantity that ranks UNSEEN blocks at partition time), so the
    hottest frontiers claim lane slots first — the PSD priority rule
    applied at admission instead of mid-run."""
    return np.argsort(-np.asarray(priority, dtype=np.float64),
                      kind="stable")


def adaptive_i2(i2: int, num_blocks: int, perturbed: int,
                max_scale: int = 8) -> int:
    """Delta-proportional cold-admission cadence for warm restarts: a batch
    that perturbs only a small fraction of the blocks admits cold blocks
    proportionally less often (up to ``max_scale`` times rarer), so the
    reconvergence effort scales with the perturbation rather than the
    graph. Batches touching >= a quarter of the blocks keep the configured
    cadence."""
    if i2 <= 0:
        return i2
    frac = perturbed / max(num_blocks, 1)
    scale = int(np.clip(round(0.25 / max(frac, 1e-9)), 1, max_scale))
    return i2 * scale
