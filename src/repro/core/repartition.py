"""Dynamic structure-based repartitioning (paper Alg. 2, §3.3).

Two modes:
  * ``barrier``  — monotone-cooling algorithms (PageRank): hot blocks only
    ever become cold, so a single integer barrier suffices ("only needs to
    maintain a Vertex_ID variable"). The barrier never moves backwards.
  * ``universal`` — non-monotone algorithms (SSSP/BFS/CC): cold blocks can
    re-heat ("cold vertices will first become hot and then converge"), so
    every block is re-labelled from its PSD against the threshold.

Re-labelling is pure bookkeeping over (P,) arrays — O(P) <= O(n) — matching
the paper's cost claim. The repartition *cadence* grows with the iteration
count (§3.3 last paragraph): interval_{k+1} = ceil(interval_k * growth).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import state


@dataclasses.dataclass
class RepartitionState:
    mode: str  # 'barrier' | 'universal'
    is_hot: np.ndarray  # (P,) bool, current labels
    barrier: int  # first cold block (barrier mode)
    interval: int  # iterations until next repartition
    growth: float = 1.5
    next_at: int = 0

    @classmethod
    def create(cls, num_blocks: int, born_barrier: int, mode: str,
               interval: int = 4, growth: float = 1.5) -> "RepartitionState":
        is_hot = np.zeros(num_blocks, dtype=bool)
        is_hot[:born_barrier] = True
        return cls(mode=mode, is_hot=is_hot, barrier=born_barrier,
                   interval=interval, growth=growth, next_at=interval)

    @classmethod
    def warm(cls, is_hot: np.ndarray, interval: int = 4,
             growth: float = 1.5) -> "RepartitionState":
        """Warm re-start over a converged state (streaming re-heat): the hot
        set is the arbitrary dirty-block mask, not a prefix barrier, so the
        mode is always 'universal' — re-heating converged blocks is exactly
        the cold->hot path, even for monotone-cooling programs."""
        is_hot = np.array(is_hot, dtype=bool)
        return cls(mode="universal", is_hot=is_hot, barrier=0,
                   interval=interval, growth=growth, next_at=interval)

    def chunk_end(self, max_iterations: int) -> int:
        """Exclusive end of the device-resident iteration chunk: the fused
        engine runs through the iteration at which the repartition cadence
        fires (inclusive), then hands control back to the host."""
        return min(self.next_at + 1, max_iterations)

    def maybe_repartition(self, iteration: int, psd: np.ndarray,
                          hot_ratio: float = 0.1) -> bool:
        """Re-label blocks if the cadence fires. Returns True if it ran."""
        if iteration < self.next_at:
            return False
        thr = state.psd_threshold(psd, hot_ratio)
        seen = psd < state.UNSEEN
        if self.mode == "barrier":
            # Move the barrier over trailing hot blocks whose activity fell
            # below the threshold. Monotone: never re-heats.
            b = self.barrier
            while b > 0 and seen[b - 1] and psd[b - 1] < thr:
                b -= 1
            self.barrier = b
            self.is_hot[:] = False
            self.is_hot[:b] = True
        else:
            hot = psd >= thr
            # unseen blocks keep their current label
            self.is_hot = np.where(seen, hot, self.is_hot)
        # growing cadence
        self.interval = max(int(np.ceil(self.interval * self.growth)),
                            self.interval + 1)
        self.next_at = iteration + self.interval
        return True
