"""End-to-end training driver (deliverable b: the e2e example).

Runs on whatever devices exist (CPU here, pods in production): builds a
("data","model") mesh over local devices, shards params/optimizer with the
same rules as the dry-run, streams the synthetic pipeline, checkpoints on a
cadence + SIGTERM, auto-resumes from the latest checkpoint, feeds the
straggler monitor, and (optionally) simulates a mid-run crash to exercise
restart (--fail-at).

Example (trains a ~100M-param llama-style model):
    PYTHONPATH=src python -m repro.launch.train --arch llama3p2_1b \
        --reduced --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import signal
import sys
import time

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.ckpt import CheckpointManager
from repro.data import SyntheticLM
from repro.ft import StragglerMonitor
from repro.launch import sharding as shard_lib
from repro.launch.mesh import make_host_mesh
from repro.models import model as model_lib
from repro.optim import AdamWConfig, adamw_init
from repro.train.step import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3p2_1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--scale", type=float, default=1.0,
                    help="width multiplier on the reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="simulate a crash after this step (FT test)")
    ap.add_argument("--expert-rebalance", action="store_true",
                    help="structure-aware expert re-binning (MoE archs): "
                         "the paper's dynamic repartitioning at runtime")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = configs.reduced(cfg)
        if args.scale != 1.0:
            s = args.scale
            cfg = dataclasses.replace(
                cfg, d_model=int(cfg.d_model * s),
                d_ff=int(cfg.d_ff * s) if cfg.d_ff else 0,
                num_layers=max(int(cfg.num_layers * s), 1))
    mesh = make_host_mesh(model=args.model_axis)
    opt_cfg = AdamWConfig(peak_lr=args.lr, total_steps=args.steps,
                          warmup_steps=min(20, args.steps // 5 + 1))
    step_fn = make_train_step(cfg, opt_cfg, num_microbatches=args.micro)

    params_shape = jax.eval_shape(
        lambda: model_lib.init_params(cfg, jax.random.PRNGKey(args.seed)))
    state_shape = {"params": params_shape,
                   "opt": {"m": params_shape, "v": params_shape,
                           "step": jax.ShapeDtypeStruct((), np.int32)}}
    sspecs = shard_lib.state_specs(state_shape, mesh)
    repl = NamedSharding(mesh, P())

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    if ckpt and ckpt.latest_step() is not None:
        state, meta = ckpt.restore(shardings=sspecs)
        state["opt"]["step"] = jax.device_put(
            np.asarray(state["opt"]["step"], np.int32), repl)
        start_step = meta["step"]
        print(f"[train] resumed from step {start_step}")
    else:
        params = jax.jit(
            lambda k: model_lib.init_params(cfg, k),
            out_shardings=sspecs["params"])(jax.random.PRNGKey(args.seed))
        state = {"params": params, "opt": adamw_init(params)}
        state["opt"] = jax.device_put(state["opt"], sspecs["opt"])

    data = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=args.seed)
    bspec = NamedSharding(mesh, P("data", None))
    jstep = jax.jit(step_fn, in_shardings=(sspecs, {"tokens": bspec,
                                                    "targets": bspec}),
                    out_shardings=(sspecs, None), donate_argnums=(0,))

    monitor = StragglerMonitor()
    rebalancer = None
    if args.expert_rebalance and cfg.num_experts:
        from repro.train.expert_balance import (ExpertRebalancer,
                                                permute_expert_axis)
        rebalancer = ExpertRebalancer(
            num_experts=cfg.experts_eff,
            num_shards=max(mesh.shape.get("model", 1), 1),
            interval=max(args.steps // 8, 5))
    stop = {"now": False}
    signal.signal(signal.SIGTERM, lambda *_: stop.update(now=True))

    losses = []
    for step in range(start_step, args.steps):
        t0 = time.perf_counter()
        batch = jax.device_put(data.batch(step), bspec)
        state, metrics = jstep(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        dt = time.perf_counter() - t0
        health = monitor.observe(dt)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"[train] step={step} loss={loss:.4f} "
                  f"lr={float(metrics['lr']):.2e} {dt*1e3:.0f}ms"
                  + (" STRAGGLER" if health["straggler"] else ""),
                  flush=True)
        if rebalancer is not None:
            perm = rebalancer.observe(
                np.asarray(metrics["expert_load"], np.float64), step + 1)
            if perm is not None:
                # function-preserving expert relabel -> balanced EP shards
                state["params"] = permute_expert_axis(state["params"], perm)
                for mom in ("m", "v"):
                    state["opt"][mom] = permute_expert_axis(
                        state["opt"][mom], perm)
                print(f"[train] step={step} expert rebalance #"
                      f"{rebalancer.moves} applied")
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, state)
        if args.fail_at is not None and step + 1 >= args.fail_at:
            print(f"[train] simulating failure at step {step + 1}")
            if ckpt:
                ckpt.save(step + 1, state)
                ckpt.wait()
            sys.exit(42)
        if stop["now"]:
            print("[train] SIGTERM: checkpointing and exiting")
            if ckpt:
                ckpt.save(step + 1, state)
                ckpt.wait()
            sys.exit(0)
    if ckpt:
        ckpt.save(args.steps, state)
        ckpt.wait()
    if losses:
        print(f"[train] done: first loss {losses[0]:.4f} -> last "
              f"{losses[-1]:.4f}")
    else:
        print(f"[train] nothing to do (resumed at step {start_step} "
              f">= {args.steps})")
    return losses


if __name__ == "__main__":
    main()
