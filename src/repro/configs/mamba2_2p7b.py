"""mamba2-2.7b [ssm]: 64L d_model=2560, attn-free SSD, state=128.
[arXiv:2405.21060; unverified]. d_inner = 2*d_model = 5120, headdim 64 ->
80 SSD heads; vocab 50280 (GPT-NeoX tokenizer, padded)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b", family="ssm",
    num_layers=64, d_model=2560, num_heads=0, num_kv_heads=0, d_ff=0,
    vocab_size=50280,
    ssm_state=128, ssm_heads=80, ssm_head_dim=64,
    tie_embeddings=True,
)
