"""granite-moe-3b-a800m [moe]: 32L d=1536 24H (GQA kv=8), 40 experts top-8,
expert width 512. [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m", family="moe",
    num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8,
    d_ff=512, vocab_size=49155,
    num_experts=40, experts_per_token=8, num_shared_experts=0,
    moe_d_ff=512,
)
