"""Per-kernel shape/dtype sweeps vs the ref.py pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref
from repro.models.ssm import causal_conv, ssd_chunked, ssd_decode_step

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("e,c", [(1, 128), (100, 256), (513, 512),
                                 (2048, 128), (5000, 1024)])
@pytest.mark.parametrize("dtype", [np.float32])
def test_spmv_sweep(e, c, dtype):
    msg = jnp.asarray(RNG.normal(size=e).astype(dtype))
    dst = jnp.asarray(RNG.integers(0, c, size=e).astype(np.int32))
    got = ops.edge_block_sum(msg, dst, c)
    want = ref.edge_block_sum(msg, dst, c)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@given(e=st.integers(1, 3000), c=st.sampled_from([128, 256, 512]),
       seed=st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_spmv_property(e, c, seed):
    rng = np.random.default_rng(seed)
    msg = jnp.asarray(rng.normal(size=e).astype(np.float32))
    dst = jnp.asarray(rng.integers(0, c, size=e).astype(np.int32))
    got = ops.edge_block_sum(msg, dst, c)
    want = ref.edge_block_sum(msg, dst, c)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b,hq,hkv,s,d", [
    (1, 4, 2, 256, 64), (2, 8, 4, 128, 128), (1, 2, 1, 512, 64),
    (1, 8, 8, 128, 64),
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, hq, hkv, s, d, causal, dtype):
    q = jnp.asarray(RNG.normal(size=(b, hq, s, d)), dtype=dtype)
    k = jnp.asarray(RNG.normal(size=(b, hkv, s, d)), dtype=dtype)
    v = jnp.asarray(RNG.normal(size=(b, hkv, s, d)), dtype=dtype)
    got = ops.flash_attention(q, k, v, causal=causal)
    want = ref.attention(q, k, v, causal=causal)
    tol = 2e-5 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(got.astype(np.float32),
                               want.astype(np.float32), rtol=tol, atol=tol)


def test_chunked_attention_matches_full():
    from repro.models.attention import chunked_attention, full_attention
    q = jnp.asarray(RNG.normal(size=(2, 2048, 4, 32)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(2, 2048, 2, 32)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(2, 2048, 2, 32)).astype(np.float32))
    got = chunked_attention(q, k, v, causal=True)
    want = full_attention(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("bsz,s,h,p,n,chunk", [
    (2, 256, 4, 16, 32, 64), (1, 128, 2, 8, 16, 128),
    (2, 512, 3, 32, 64, 128),
])
def test_ssd_chunked_vs_scan_oracle(bsz, s, h, p, n, chunk):
    x = jnp.asarray(RNG.normal(size=(bsz, s, h, p)).astype(np.float32))
    a_log = jnp.asarray(RNG.uniform(0, 2, size=(h,)).astype(np.float32))
    b = jnp.asarray(RNG.normal(size=(bsz, s, n)).astype(np.float32))
    c = jnp.asarray(RNG.normal(size=(bsz, s, n)).astype(np.float32))
    dt = jnp.asarray(RNG.uniform(1e-3, 0.1, (bsz, s, h)).astype(np.float32))
    got = ssd_chunked(x, a_log, b, c, dt, chunk=chunk)
    want = ref.ssd_scan(x, a_log, b, c, dt)
    scale = float(jnp.abs(want).max())
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4 * scale)


def test_ssd_decode_continues_prefill():
    bsz, s, h, p, n = 1, 64, 2, 8, 16
    x = jnp.asarray(RNG.normal(size=(bsz, s, h, p)).astype(np.float32))
    a_log = jnp.asarray(RNG.uniform(0, 2, size=(h,)).astype(np.float32))
    b = jnp.asarray(RNG.normal(size=(bsz, s, n)).astype(np.float32))
    c = jnp.asarray(RNG.normal(size=(bsz, s, n)).astype(np.float32))
    dt = jnp.asarray(RNG.uniform(1e-3, 0.1, (bsz, s, h)).astype(np.float32))
    y_pre, state = ssd_chunked(x[:, :32], a_log, b[:, :32], c[:, :32],
                               dt[:, :32], chunk=32, return_state=True)
    ys = []
    for t in range(32, s):
        state, y = ssd_decode_step(state, x[:, t], a_log, b[:, t], c[:, t],
                                   dt[:, t])
        ys.append(y)
    y_dec = jnp.stack(ys, 1)
    y_full = ssd_chunked(x, a_log, b, c, dt, chunk=32)
    np.testing.assert_allclose(y_dec, y_full[:, 32:], rtol=1e-4, atol=1e-4)


def test_causal_conv_streaming():
    x = jnp.asarray(RNG.normal(size=(2, 16, 6)).astype(np.float32))
    w = jnp.asarray(RNG.normal(size=(4, 6)).astype(np.float32))
    full, _ = causal_conv(x, w)
    cache = jnp.zeros((2, 3, 6))
    outs = []
    for t in range(16):
        o, cache = causal_conv(x[:, t:t + 1], w, cache)
        outs.append(o)
    np.testing.assert_allclose(jnp.concatenate(outs, 1), full,
                               rtol=1e-5, atol=1e-5)


def test_engine_with_pallas_spmv_matches():
    """The engine's sum-combine path through the Pallas kernel (interpret)
    reaches the same fixpoint."""
    from repro.core import algorithms as A, graph as G
    from repro.core.engine import EngineConfig, StructureAwareEngine
    g = G.powerlaw_graph(600, 4, seed=7)
    cfg = EngineConfig(t2=1e-9, width=4, block_size=128)
    plain = StructureAwareEngine(g, A.pagerank(), cfg).run()
    pallas = StructureAwareEngine(
        g, A.pagerank(),
        EngineConfig(t2=1e-9, width=4, block_size=128, use_pallas=True)
    ).run()
    np.testing.assert_allclose(plain.values, pallas.values,
                               rtol=1e-5, atol=1e-8)


@pytest.mark.parametrize("g,q,n,p", [(4, 64, 32, 16), (2, 128, 128, 64),
                                     (6, 128, 64, 128)])
def test_ssd_intra_chunk_kernel(g, q, n, p):
    """Pallas SSD intra-chunk kernel vs the einsum oracle."""
    c = jnp.asarray(RNG.normal(size=(g, q, n)).astype(np.float32))
    b = jnp.asarray(RNG.normal(size=(g, q, n)).astype(np.float32))
    u = jnp.asarray(RNG.normal(size=(g, q, p)).astype(np.float32))
    ld = jnp.asarray(np.cumsum(
        RNG.uniform(-0.1, 0, size=(g, q)).astype(np.float32), axis=1))
    got = ops.ssd_intra_chunk(c, b, u, ld)
    gram = jnp.einsum("gqn,gsn->gqs", c, b)
    ldiff = ld[:, :, None] - ld[:, None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(mask[None], jnp.exp(ldiff), 0.0)
    want = jnp.einsum("gqs,gsp->gqp", gram * decay, u)
    np.testing.assert_allclose(got, want, rtol=1e-5,
                               atol=1e-4 * float(jnp.abs(want).max()))
