"""Bitwise-parity property suite for the fused Pallas block sweep.

The acceptance bar from the kernel module's docstring: ``use_pallas=True``
must be indistinguishable from the dense reference — VALUES bitwise equal
and EVERY counter (iterations, updates, edges processed, block loads,
bytes loaded) identical — for sum- and min-combine programs, single-lane
and lane-batched, fused and host execution, flat and sub-block-masked
sweeps, with and without padding lanes. Anything weaker (allclose) would
let the kernel drift into a second implementation of the algorithm; these
tests pin it as a re-expression of the same one.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import algorithms as A
from repro.core import graph as G
from repro.core.engine import (EngineConfig, StructureAwareEngine,
                               coupling_from_counts)
from repro.kernels import ops, ref
from repro.serve.lanes import LaneEngine
from repro.stream import StreamingEngine

RNG = np.random.default_rng(0)

_COUNTERS = ("iterations", "updates", "edges_processed", "block_loads",
             "bytes_loaded", "converged")

_PROGRAMS = {
    "pagerank": lambda: A.pagerank(),     # sum combine
    "sssp": lambda: A.sssp(0),            # min combine, weighted
    "cc": lambda: A.cc(),                 # min combine, label propagation
}

_FAMILIES = {
    "k_sssp": (lambda: A.k_source_sssp(), [3, 77]),    # min
    "k_bfs": (lambda: A.k_source_bfs(), [1, 40]),      # min, unweighted
    "ppr": (lambda: A.k_personalized_pagerank(),
            [[2], [9, 11]]),                           # sum (MXU combine)
}


def _assert_counters(mp, md, label):
    for f in _COUNTERS:
        assert getattr(mp, f) == getattr(md, f), \
            f"{label}: counter {f} diverged: {getattr(mp, f)} " \
            f"vs {getattr(md, f)}"


# -- per-tile segmented min/max kernels vs the scatter oracles ---------------
@pytest.mark.parametrize("e,c", [(1, 128), (100, 256), (513, 512),
                                 (2048, 128)])
@pytest.mark.parametrize("combine", ["min", "max"])
def test_seg_select_sweep(e, c, combine):
    ident = 1e18 if combine == "min" else -1e18
    msg = jnp.asarray(RNG.normal(size=e).astype(np.float32))
    dst = jnp.asarray(RNG.integers(0, c, size=e).astype(np.int32))
    fn = ops.edge_block_min if combine == "min" else ops.edge_block_max
    rfn = ref.edge_block_min if combine == "min" else ref.edge_block_max
    got = fn(msg, dst, c, ident)
    want = rfn(msg, dst, c, ident)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@given(e=st.integers(1, 3000), c=st.sampled_from([128, 256, 512]),
       seed=st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_seg_min_property_bitwise(e, c, seed):
    rng = np.random.default_rng(seed)
    msg = jnp.asarray(rng.normal(size=e).astype(np.float32))
    dst = jnp.asarray(rng.integers(0, c, size=e).astype(np.int32))
    got = ops.edge_block_min(msg, dst, c, 1e18)
    want = ref.edge_block_min(msg, dst, c, 1e18)
    assert np.array_equal(np.asarray(got), np.asarray(want))


# -- single-lane engine parity: fused device loop ----------------------------
@given(n=st.integers(200, 500), avg=st.integers(3, 6),
       seed=st.integers(0, 1000),
       prog=st.sampled_from(sorted(_PROGRAMS)),
       subblocks=st.sampled_from([1, 8]))
@settings(max_examples=6, deadline=None)
def test_fused_sweep_bitwise_property(n, avg, seed, prog, subblocks):
    g = G.powerlaw_graph(n, avg, seed=seed, weighted=(prog == "sssp"))
    program = _PROGRAMS[prog]()
    kw = dict(t2=1e-9, width=4, block_size=64, subblocks=subblocks)
    rd = StructureAwareEngine(g, program, EngineConfig(**kw)).run()
    rp = StructureAwareEngine(
        g, program, EngineConfig(use_pallas=True, **kw)).run()
    assert np.array_equal(rd.values, rp.values), \
        f"{prog} sb={subblocks}: values not bitwise"
    _assert_counters(rp.metrics, rd.metrics, f"{prog} sb={subblocks}")


# -- single-lane engine parity: host-driven reference loop -------------------
@pytest.mark.parametrize("prog", ["pagerank", "sssp"])
def test_host_path_bitwise(prog):
    g = G.powerlaw_graph(300, 4, seed=5, weighted=(prog == "sssp"))
    program = _PROGRAMS[prog]()
    kw = dict(t2=1e-9, width=4, block_size=64, subblocks=8)
    rd = StructureAwareEngine(g, program,
                              EngineConfig(**kw)).run(fused=False)
    rp = StructureAwareEngine(
        g, program, EngineConfig(use_pallas=True, **kw)).run(fused=False)
    assert np.array_equal(rd.values, rp.values)
    _assert_counters(rp.metrics, rd.metrics, f"host {prog}")


# -- lane-batched parity (the PPR scatter fix) -------------------------------
def _lane_pair(n, seed, family, subblocks, padding):
    g = G.powerlaw_graph(n, avg_deg=5, seed=seed, weighted=True)
    cfg = EngineConfig(t2=1e-9, width=4, block_size=64,
                       subblocks=subblocks)
    se = StreamingEngine(g, A.pagerank(), cfg)
    es = se.snapshot()
    factory, params = _FAMILIES[family]
    fam = factory()
    vals0, vconst = fam.lane_init(se.n, params)
    lane_active = np.array([True, not padding])
    ed = es.ed if family == "ppr" else es.ed._replace(
        aux=jnp.zeros(se.n, jnp.float32))
    kw = dict(ed=ed,
              coupling=coupling_from_counts(es.coupling_counts, fam,
                                            es.engine.plan.block_size),
              values0=vals0, vconst=vconst, lane_active=lane_active,
              edge_counts=es.edge_counts)
    rd = LaneEngine(es.engine, fam, use_pallas=False).run(**kw)
    rp = LaneEngine(es.engine, fam, use_pallas=True).run(**kw)
    return rd, rp


@given(seed=st.integers(0, 1000),
       family=st.sampled_from(sorted(_FAMILIES)),
       subblocks=st.sampled_from([1, 8]),
       padding=st.booleans())
@settings(max_examples=6, deadline=None)
def test_lane_sweep_bitwise_property(seed, family, subblocks, padding):
    rd, rp = _lane_pair(400, seed, family, subblocks, padding)
    label = f"{family} sb={subblocks} pad={padding}"
    assert np.array_equal(rd.values, rp.values), \
        f"{label}: values not bitwise"
    _assert_counters(rp.metrics, rd.metrics, label)
    assert np.array_equal(rd.lane_iterations, rp.lane_iterations), label
    assert np.array_equal(rd.lane_converged, rp.lane_converged), label


def test_lane_engine_inherits_engine_flag():
    """LaneEngine(use_pallas=None) follows the geometry owner's config, so
    a Pallas engine serves Pallas lanes without restating the flag."""
    g = G.powerlaw_graph(200, 4, seed=0)
    eng = StructureAwareEngine(
        g, A.pagerank(),
        EngineConfig(block_size=64, width=2, use_pallas=True))
    assert LaneEngine(eng, A.k_source_sssp()).use_pallas is True
    assert LaneEngine(eng, A.k_source_sssp(),
                      use_pallas=False).use_pallas is False


def test_service_use_pallas_plumbing():
    """QueryService(use_pallas=True) answers bitwise-identically to the
    dense service over the same streaming engine."""
    from repro.serve import Query, QueryService
    g = G.powerlaw_graph(400, avg_deg=5, seed=7, weighted=True)
    cfg = EngineConfig(t2=1e-9, width=4, block_size=64)
    results = {}
    for flag in (False, True):
        se = StreamingEngine(g, A.pagerank(), cfg)
        svc = QueryService(se, max_lanes=2, prewarm=False,
                           use_pallas=flag)
        svc.submit(Query(kind="sssp", source=3))
        svc.submit(Query(kind="sssp", source=11))
        results[flag] = svc.run_pending()
    for rd, rp in zip(results[False], results[True]):
        assert np.array_equal(rd.values, rp.values)
        assert rd.iterations == rp.iterations
