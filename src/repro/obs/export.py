"""Chrome-trace / Perfetto JSON export of a :class:`TraceRecorder`.

Emits the Trace Event Format ``{"traceEvents": [...]}`` JSON object
(the format chrome://tracing and https://ui.perfetto.dev load
directly): spans as complete events (``"ph": "X"``, ``ts``/``dur`` in
microseconds), per-superstep counters as counter events (``"ph": "C"``
— Perfetto plots each ``args`` key as a series), instants as
``"ph": "i"``, plus one metadata event naming the process.

:func:`validate` is the schema check the CI trace-smoke step (and the
``python -m repro.obs validate`` CLI) runs over exported payloads, so a
field drift here fails the build instead of silently producing a file
the viewers reject.
"""
from __future__ import annotations

import json

_PID = 1
_TID = 1
_VALID_PH = {"X", "C", "M", "i", "I"}


def to_chrome(recorder, meta: dict | None = None) -> dict:
    """Convert a recorder's ring buffer to a Chrome-trace JSON object."""
    events = [{"name": "process_name", "ph": "M", "pid": _PID, "tid": _TID,
               "args": {"name": "repro"}}]
    for ev in recorder.events:
        base = {"name": ev["name"], "cat": ev.get("cat") or "default",
                "pid": _PID, "tid": _TID,
                "ts": round(ev["ts"] * 1e6, 3)}
        if ev["type"] == "span":
            events.append({**base, "ph": "X",
                           "dur": round(ev["dur"] * 1e6, 3),
                           "args": ev["args"]})
        elif ev["type"] == "counter":
            events.append({**base, "ph": "C", "args": ev["values"]})
        elif ev["type"] == "instant":
            events.append({**base, "ph": "i", "s": "t",
                           "args": ev["args"]})
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"dropped_events": recorder.dropped,
                          **(meta or {})}}


def write(recorder, path: str, meta: dict | None = None) -> str:
    """Export ``recorder`` to ``path`` as Chrome-trace JSON; returns the
    path."""
    with open(path, "w") as f:
        json.dump(to_chrome(recorder, meta), f, indent=1)
    return path


def validate(payload: dict) -> list[str]:
    """Chrome-trace schema check. Returns a list of human-readable
    errors — empty means the payload is loadable by chrome://tracing /
    Perfetto. Checks the envelope, per-event required fields, phase
    codes, and numeric ts/dur/counter values."""
    errors: list[str] = []
    if not isinstance(payload, dict):
        return [f"payload is {type(payload).__name__}, expected object"]
    events = payload.get("traceEvents")
    if not isinstance(events, list):
        return ["missing or non-list 'traceEvents'"]
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in _VALID_PH:
            errors.append(f"{where}: bad phase {ph!r}")
            continue
        if not isinstance(ev.get("name"), str):
            errors.append(f"{where}: missing string 'name'")
        for field in ("pid", "tid"):
            if not isinstance(ev.get(field), int):
                errors.append(f"{where}: missing int '{field}'")
        if ph == "M":
            continue  # metadata events carry no timestamp
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or isinstance(ts, bool) \
                or ts < 0:
            errors.append(f"{where}: bad 'ts' {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or isinstance(dur, bool) \
                    or dur < 0:
                errors.append(f"{where}: bad 'dur' {dur!r} on X event")
        if ph == "C":
            args = ev.get("args")
            if not isinstance(args, dict) or not args:
                errors.append(f"{where}: C event needs non-empty args")
            else:
                for k, v in args.items():
                    if not isinstance(v, (int, float)) \
                            or isinstance(v, bool):
                        errors.append(
                            f"{where}: counter '{k}' non-numeric {v!r}")
        if ph == "i" and ev.get("s", "t") not in ("g", "p", "t"):
            errors.append(f"{where}: bad instant scope {ev.get('s')!r}")
    return errors
