"""Paper Table 1 analogue: end-to-end comparison, structure-aware engine vs
Gemini-style dense baseline, 4 vertex algorithms x 3 graph families
(+ BC via the betweenness driver).

Columns: runtime, iterations-to-convergence, vertex updates, partition
loads (cache-miss proxy), bytes loaded (I/O proxy). The paper's headline is
"double the performance"; the reproduction's primary wins are updates and
partition loads (see EXPERIMENTS.md §Paper-validation for the wall-clock
discussion on CPU vs the paper's cluster)."""
from __future__ import annotations

import numpy as np

from repro.core import algorithms as A
from repro.core import graph as G
from repro.core.baseline import BaselineEngine
from repro.core.engine import EngineConfig, StructureAwareEngine, betweenness


def graphs(n: int):
    return {
        "powerlaw": G.powerlaw_graph(n, avg_deg=8, seed=1, weighted=True),
        "coreperiph": G.core_periphery_graph(n, avg_deg=8, seed=1,
                                             chords=1, weighted=True),
        "road": G.uniform_graph(n // 4, deg=4, seed=2, weighted=True),
    }


def run(n: int = 20000):
    cfg = EngineConfig(t2=1e-8, width=16, block_size=512)
    rows = []
    for gname, g in graphs(n).items():
        for aname, mk in [("pagerank", A.pagerank), ("cc", A.cc),
                          ("sssp", lambda: A.sssp(0)),
                          ("bfs", lambda: A.bfs(0))]:
            base = BaselineEngine(g, mk(), cfg, frontier=False).run()
            sa = StructureAwareEngine(g, mk(), cfg).run()
            agree = np.allclose(np.minimum(base.values, 1e18),
                                np.minimum(sa.values, 1e18),
                                rtol=1e-3, atol=1e-5)
            mb, ms = base.metrics, sa.metrics
            rows.append((
                f"runtime/{gname}/{aname}/base",
                mb.wall_time_s * 1e6 / max(mb.iterations, 1),
                f"iters={mb.iterations};updates={mb.updates};"
                f"loads={mb.block_loads};MB={mb.bytes_loaded/1e6:.1f}"))
            rows.append((
                f"runtime/{gname}/{aname}/sa",
                ms.wall_time_s * 1e6 / max(ms.iterations, 1),
                f"iters={ms.iterations};updates={ms.updates};"
                f"loads={ms.block_loads};MB={ms.bytes_loaded/1e6:.1f};"
                f"agree={agree};upd_gain={mb.updates/max(ms.updates,1):.2f}x;"
                f"load_gain={mb.block_loads/max(ms.block_loads,1):.2f}x;"
                f"io_gain={mb.bytes_loaded/max(ms.bytes_loaded,1):.2f}x"))
        # fused vs host-driven loop (device-resident superstep tentpole):
        # steady-state us/iteration with the per-iteration host round-trip
        # eliminated. Both paths are warmed first (incl. every adaptive
        # dispatch-width bucket) so compile time does not pollute the
        # ratio; the host loop is iteration-capped because a full
        # host-driven convergence run IS the slow thing being removed.
        eng = StructureAwareEngine(g, A.pagerank(), cfg)
        eng.prewarm_buckets()                    # compile all width buckets
        eng.run(max_iterations=2)                # warm the fused entry path
        eng.run(max_iterations=2, fused=False)   # compile the host-loop fns
        fast = eng.run(max_iterations=32)
        slow = eng.run(max_iterations=8, fused=False)
        us_f = fast.metrics.wall_time_s * 1e6 / max(fast.metrics.iterations,
                                                    1)
        us_h = slow.metrics.wall_time_s * 1e6 / max(slow.metrics.iterations,
                                                    1)
        rows.append((f"runtime/{gname}/pagerank/sa_fused_loop", us_f,
                     f"iters={fast.metrics.iterations};"
                     f"speedup_vs_hostloop={us_h / max(us_f, 1e-9):.2f}x"))
        rows.append((f"runtime/{gname}/pagerank/sa_host_loop", us_h,
                     f"iters={slow.metrics.iterations};capped=True"))
        # tracing-on overhead: the SAME fused 32-iteration run with the
        # per-superstep history buffer in the carry. First traced run
        # compiles the history-capacity bucket ladder (prewarm_buckets
        # only warms the untraced executables); the second is the timed
        # one. The derived overhead ratio is against the untraced fused
        # row above, measured in the same repeat.
        eng.run(max_iterations=32, trace=True)   # compile traced buckets
        tr = eng.run(max_iterations=32, trace=True)
        us_t = tr.metrics.wall_time_s * 1e6 / max(tr.metrics.iterations, 1)
        rows.append((
            f"runtime/{gname}/pagerank/sa_fused_loop_traced", us_t,
            f"iters={tr.metrics.iterations};"
            f"timeline_rows={len(tr.timeline or ())};"
            f"overhead_vs_untraced={us_t / max(us_f, 1e-9):.3f}x"))
        # cold full-run time-to-convergence on the warmed engine: the
        # adaptive active-set claim (retirement + shrinking width + depth
        # ladder) pays off in the TAIL iterations, which the 32-iteration
        # cap above never reaches. us_per_call = full wall time.
        full = eng.run()
        mf = full.metrics
        rows.append((
            f"runtime/{gname}/pagerank/sa_fused_full",
            mf.wall_time_s * 1e6,
            f"iters={mf.iterations};converged={mf.converged};"
            f"updates={mf.updates};retired={mf.blocks_retired};"
            f"mean_width={mf.mean_dispatch_width:.1f};"
            "depth_hist=" + "|".join(
                f"{d}:{c}" for d, c in sorted(mf.inner_depth_hist.items(),
                                              reverse=True))))
        # BC (sampled sources)
        bc_b, m_b = betweenness(g, [0, 1], cfg, structure_aware=False)
        bc_s, m_s = betweenness(g, [0, 1], cfg, structure_aware=True)
        agree = np.allclose(bc_b, bc_s, rtol=1e-3, atol=1e-5)
        rows.append((f"runtime/{gname}/bc/base",
                     m_b.wall_time_s * 1e6 / max(m_b.iterations, 1),
                     f"updates={m_b.updates};loads={m_b.block_loads}"))
        rows.append((f"runtime/{gname}/bc/sa",
                     m_s.wall_time_s * 1e6 / max(m_s.iterations, 1),
                     f"updates={m_s.updates};loads={m_s.block_loads};"
                     f"agree={agree};"
                     f"upd_gain={m_b.updates/max(m_s.updates,1):.2f}x"))
    return rows
