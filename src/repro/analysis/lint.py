"""Repo-specific AST lint rules for ``src/repro``.

Rules (all stdlib ``ast``, no jax import — this layer must run even
where jax is broken):

  RA001  host-sync primitive inside traced code: ``.item()``,
         ``np.asarray``/``np.array``, ``jax.device_get``,
         ``float()``/``int()`` on a traced parameter — inside a function
         that is jitted, passed to a ``lax`` control-flow combinator, or
         returned by a ``make_*`` trace factory. These either fail at
         trace time or silently force a device->host transfer per call.
  RA002  read after donation: a buffer passed through a
         ``donate_argnums`` position of a locally-built jit is dead; any
         later read before rebinding aliases freed device memory.
  RA003  loop-varying closure capture in traced code: a traced function
         capturing a name the enclosing function rebinds per loop
         iteration (``for`` target or ``+=``) recompiles per distinct
         value — the i2 recompile hazard. Loop-invariant captures
         (width, floors, depths) are the intended idiom and are not
         flagged.
  RA004  nondeterminism in schedule-affecting code: clocks or unseeded
         randomness in the scheduler/partition/prefetch modules (any
         module holding a ``@deterministic`` contract, plus a fixed
         list). The OOC tier's bitwise guarantee assumes ranking is a
         pure function of the activity state.

A finding can be suppressed with ``# lint: allow(RAxxx)`` on the line.
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path

# Modules where RA004 applies even without a @deterministic marker: the
# schedule decisions and everything that predicts or ranks for them.
SCHEDULE_AFFECTING = (
    "core/schedule.py",
    "core/partition.py",
    "ooc/prefetch.py",
    "ooc/store.py",
)

# lax combinators -> positions of their traced callees
_CALLBACK_POSITIONS = {
    "while_loop": (0, 1),
    "fori_loop": (2,),
    "cond": (1, 2),
    "switch": None,  # every arg from 1 on is a branch callee
    "scan": (0,),
    "map": (0,),
    "associative_scan": (0,),
    "pallas_call": (0,),
    "checkpoint": (0,),
    "vmap": (0,),
    "pmap": (0,),
    "grad": (0,),
    "value_and_grad": (0,),
}

_HOST_SYNC_CALLS = {
    ("np", "asarray"), ("np", "array"), ("numpy", "asarray"),
    ("numpy", "array"), ("jax", "device_get"), ("onp", "asarray"),
}

_NONDET_PREFIXES = (
    ("time",), ("random",), ("np", "random"), ("numpy", "random"),
    ("os", "urandom"), ("uuid",),
)
_NONDET_SEEDED_OK = {"default_rng", "Generator", "SeedSequence"}


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    msg: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.msg}"


def _attr_chain(node: ast.AST) -> tuple[str, ...]:
    """``jax.lax.while_loop`` -> ("jax", "lax", "while_loop")."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    else:
        return ()
    return tuple(reversed(parts))


def _is_jit_expr(node: ast.AST) -> bool:
    """``jax.jit``/``jit``, or ``functools.partial(jax.jit, ...)``."""
    chain = _attr_chain(node)
    if chain and chain[-1] == "jit":
        return True
    if isinstance(node, ast.Call):
        fchain = _attr_chain(node.func)
        if fchain and fchain[-1] == "partial" and node.args:
            return _is_jit_expr(node.args[0])
    return False


def _callee_exprs(call: ast.Call) -> list[ast.AST]:
    """Expressions holding traced callees for a jit/lax-combinator call,
    or [] if this call introduces no trace roots."""
    if _is_jit_expr(call.func):
        # functools.partial(jax.jit, ...) is itself the wrapper — its
        # remaining args are jit options, not callees
        return list(call.args[:1])
    chain = _attr_chain(call.func)
    if not chain:
        return []
    name = chain[-1]
    if name not in _CALLBACK_POSITIONS:
        return []
    if name == "switch":
        return list(call.args[1:])
    out = []
    for pos in _CALLBACK_POSITIONS[name]:
        if pos < len(call.args):
            out.append(call.args[pos])
    return out


def _unwrap_callee(expr: ast.AST) -> list[ast.AST]:
    """Resolve a callee expression to name/lambda nodes (IfExp branches,
    functools.partial first arg)."""
    if isinstance(expr, ast.IfExp):
        return _unwrap_callee(expr.body) + _unwrap_callee(expr.orelse)
    if isinstance(expr, ast.Call):
        chain = _attr_chain(expr.func)
        if chain and chain[-1] == "partial" and expr.args:
            return _unwrap_callee(expr.args[0])
        return []
    if isinstance(expr, (ast.Name, ast.Lambda)):
        return [expr]
    return []


_FuncNode = (ast.FunctionDef, ast.AsyncFunctionDef)


class _ModuleIndex:
    """Per-module maps: every function def, parentage, and name lookup."""

    def __init__(self, tree: ast.Module):
        self.defs: list[ast.AST] = []
        self.by_name: dict[str, list[ast.AST]] = {}
        self.parent_fn: dict[int, ast.AST | None] = {}

        def walk(node: ast.AST, fn: ast.AST | None) -> None:
            for child in ast.iter_child_nodes(node):
                nfn = fn
                if isinstance(child, _FuncNode):
                    self.defs.append(child)
                    self.by_name.setdefault(child.name, []).append(child)
                    self.parent_fn[id(child)] = fn
                    nfn = child
                elif isinstance(child, ast.Lambda):
                    self.defs.append(child)
                    self.parent_fn[id(child)] = fn
                    nfn = child
                walk(child, nfn)

        walk(tree, None)


def _trace_roots(tree: ast.Module, index: _ModuleIndex) -> set[int]:
    """Node ids of functions whose bodies are traced by jax: jit
    targets, lax-combinator callees, jit-decorated defs, functions
    returned by ``make_*`` factories — closed over same-module calls."""
    roots: set[int] = set()

    def mark(expr: ast.AST, scope_fn: ast.AST | None) -> None:
        for node in _unwrap_callee(expr):
            if isinstance(node, ast.Lambda):
                roots.add(id(node))
            elif isinstance(node, ast.Name):
                for d in index.by_name.get(node.id, []):
                    roots.add(id(d))

    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            for expr in _callee_exprs(node):
                mark(expr, None)
        if isinstance(node, _FuncNode):
            for deco in node.decorator_list:
                if _is_jit_expr(deco) or (
                        isinstance(deco, ast.Call)
                        and _is_jit_expr(deco)):
                    roots.add(id(node))
            # trace factories: functions named make_* whose return value
            # is a locally-defined function (the engine idiom for
            # building traced closures: make_device_select,
            # make_tiled_processor, make_lane_processor)
            if node.name.startswith("make_"):
                for sub in ast.walk(node):
                    if isinstance(sub, ast.Return) and isinstance(
                            sub.value, ast.Name):
                        for d in index.by_name.get(sub.value.id, []):
                            roots.add(id(d))

    # fixpoint: a function called by name from a root body is traced too
    changed = True
    while changed:
        changed = False
        for d in list(index.defs):
            if id(d) not in roots:
                continue
            body = d.body if isinstance(d, _FuncNode) else [d.body]
            for stmt in body:
                for node in ast.walk(stmt):
                    if (isinstance(node, ast.Call)
                            and isinstance(node.func, ast.Name)):
                        for cd in index.by_name.get(node.func.id, []):
                            if id(cd) not in roots:
                                roots.add(id(cd))
                                changed = True
    return roots


def _allowed(source_lines: list[str], line: int, rule: str) -> bool:
    if 1 <= line <= len(source_lines):
        return f"lint: allow({rule})" in source_lines[line - 1]
    return False


def _check_host_sync(path: str, index: _ModuleIndex, roots: set[int],
                     lines: list[str]) -> list[Finding]:
    out = []
    for d in index.defs:
        if id(d) not in roots or not isinstance(d, _FuncNode):
            continue
        params = {a.arg for a in (d.args.args + d.args.kwonlyargs
                                  + d.args.posonlyargs)}
        for node in ast.walk(d):
            if not isinstance(node, ast.Call):
                continue
            msg = None
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "item" and not node.args):
                msg = ".item() forces a device->host sync"
            chain = _attr_chain(node.func)
            if chain in _HOST_SYNC_CALLS:
                msg = f"{'.'.join(chain)}() materializes on host"
            if chain and chain[-1] == "device_get":
                msg = "jax.device_get inside traced code"
            if (isinstance(node.func, ast.Name)
                    and node.func.id in ("float", "int") and node.args
                    and isinstance(node.args[0], ast.Name)
                    and node.args[0].id in params):
                msg = (f"{node.func.id}() on traced parameter "
                       f"'{node.args[0].id}'")
            if msg and not _allowed(lines, node.lineno, "RA001"):
                out.append(Finding("RA001", path, node.lineno,
                                   f"host sync in traced '{d.name}': "
                                   f"{msg}"))
    return out


def _stmt_names(node: ast.AST, ctx: type) -> list[ast.Name]:
    return [n for n in ast.walk(node)
            if isinstance(n, ast.Name) and isinstance(n.ctx, ctx)]


def _check_read_after_donate(path: str, index: _ModuleIndex,
                             lines: list[str]) -> list[Finding]:
    out = []
    for d in index.defs:
        if not isinstance(d, _FuncNode):
            continue
        # name -> donated positional indices, for jits built in this scope
        donmap: dict[str, tuple[int, ...]] = {}
        for node in ast.walk(d):
            if (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and isinstance(node.value, ast.Call)
                    and _is_jit_expr(node.value.func)):
                positions: tuple[int, ...] = ()
                for kw in node.value.keywords:
                    if kw.arg == "donate_argnums":
                        if isinstance(kw.value, ast.Tuple):
                            elts = kw.value.elts
                        elif isinstance(kw.value, ast.Constant):
                            elts = [kw.value]
                        else:
                            elts = []  # computed (e.g. tuple(range(na)))
                        positions = tuple(
                            e.value for e in elts
                            if isinstance(e, ast.Constant)
                            and isinstance(e.value, int))
                        if not elts:
                            positions = ("*",)  # all positional args
                if positions:
                    donmap[node.targets[0].id] = positions
        if not donmap:
            continue

        dead: dict[str, int] = {}  # var -> donation line

        def scan(stmts: list[ast.stmt]) -> None:
            for stmt in stmts:
                # 1. reads of already-dead buffers
                for n in _stmt_names(stmt, ast.Load):
                    if n.id in dead and not _allowed(
                            lines, n.lineno, "RA002"):
                        out.append(Finding(
                            "RA002", path, n.lineno,
                            f"'{n.id}' read after being donated at line "
                            f"{dead[n.id]} (buffer freed on device)"))
                        dead.pop(n.id)  # report once
                # 2. donations made by this statement
                for node in ast.walk(stmt):
                    if (isinstance(node, ast.Call)
                            and isinstance(node.func, ast.Name)
                            and node.func.id in donmap):
                        pos = donmap[node.func.id]
                        if pos == ("*",):
                            args = node.args
                        else:
                            args = [node.args[p] for p in pos
                                    if isinstance(p, int)
                                    and p < len(node.args)]
                        for a in args:
                            if isinstance(a, ast.Starred) and isinstance(
                                    a.value, ast.Name):
                                dead[a.value.id] = node.lineno
                            elif isinstance(a, ast.Name):
                                dead[a.id] = node.lineno
                # 3. rebinds revive
                for n in _stmt_names(stmt, ast.Store):
                    dead.pop(n.id, None)
                # recurse into compound bodies in order (branches are
                # treated sequentially — over-approximate but stable)
                for field in ("body", "orelse", "finalbody"):
                    sub = getattr(stmt, field, None)
                    if sub and isinstance(sub[0], ast.stmt):
                        scan(sub)
                for handler in getattr(stmt, "handlers", []) or []:
                    scan(handler.body)

        scan(d.body)
    return out


def _check_loop_closure(path: str, index: _ModuleIndex, roots: set[int],
                        lines: list[str]) -> list[Finding]:
    out = []
    for d in index.defs:
        if id(d) not in roots or not isinstance(d, _FuncNode):
            continue
        parent = index.parent_fn.get(id(d))
        if parent is None or not isinstance(parent, _FuncNode):
            continue
        # names bound in d (params + local stores) are not captures
        local = {a.arg for a in (d.args.args + d.args.kwonlyargs
                                 + d.args.posonlyargs)}
        local |= {n.id for n in _stmt_names(d, ast.Store)}
        # loop-varying names in the ENCLOSING function: for-targets and
        # augmented assignments outside d itself
        varying: dict[str, int] = {}
        for node in ast.walk(parent):
            if any(node is x for x in ast.walk(d)):
                continue
            if isinstance(node, (ast.For, ast.AsyncFor)):
                for n in _stmt_names(node.target, ast.Store):
                    varying[n.id] = node.lineno
            if isinstance(node, ast.AugAssign) and isinstance(
                    node.target, ast.Name):
                varying[node.target.id] = node.lineno
        for n in _stmt_names(d, ast.Load):
            if (n.id in varying and n.id not in local
                    and not _allowed(lines, n.lineno, "RA003")):
                out.append(Finding(
                    "RA003", path, n.lineno,
                    f"traced '{d.name}' captures loop-varying "
                    f"'{n.id}' (rebound at line {varying[n.id]} of "
                    f"'{parent.name}') — pass it as a traced argument "
                    f"or one executable compiles per value"))
                local.add(n.id)  # report once per name
    return out


def _check_nondeterminism(path: str, tree: ast.Module,
                          lines: list[str]) -> list[Finding]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        chain = _attr_chain(node.func)
        if not chain:
            continue
        for prefix in _NONDET_PREFIXES:
            if chain[:len(prefix)] == prefix and len(chain) > len(prefix) \
                    or chain == prefix:
                if chain[-1] in _NONDET_SEEDED_OK:
                    break
                if not _allowed(lines, node.lineno, "RA004"):
                    out.append(Finding(
                        "RA004", path, node.lineno,
                        f"'{'.'.join(chain)}' in schedule-affecting "
                        f"module (ranking must be a pure function of "
                        f"activity state)"))
                break
    return out


def _is_schedule_affecting(path: str, tree: ast.Module) -> bool:
    norm = path.replace("\\", "/")
    if any(norm.endswith(suffix) for suffix in SCHEDULE_AFFECTING):
        return True
    # any module with a @deterministic contract marker opts in
    for node in ast.walk(tree):
        if isinstance(node, _FuncNode):
            for deco in node.decorator_list:
                chain = _attr_chain(deco)
                if chain and chain[-1] == "deterministic":
                    return True
    return False


def lint_file(path: str | Path) -> list[Finding]:
    path = str(path)
    src = Path(path).read_text()
    try:
        tree = ast.parse(src)
    except SyntaxError as e:
        return [Finding("RA000", path, e.lineno or 0,
                        f"syntax error: {e.msg}")]
    lines = src.splitlines()
    index = _ModuleIndex(tree)
    roots = _trace_roots(tree, index)
    findings = []
    findings += _check_host_sync(path, index, roots, lines)
    findings += _check_read_after_donate(path, index, lines)
    findings += _check_loop_closure(path, index, roots, lines)
    if _is_schedule_affecting(path, tree):
        findings += _check_nondeterminism(path, tree, lines)
    return findings


def lint_paths(paths: list[str | Path]) -> list[Finding]:
    """Lint every ``.py`` file under the given files/directories."""
    files: list[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            files += sorted(f for f in p.rglob("*.py")
                            if "__pycache__" not in f.parts)
        else:
            files.append(p)
    findings: list[Finding] = []
    for f in files:
        findings += lint_file(f)
    return findings
