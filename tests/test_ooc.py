"""Out-of-core block tier + epoch persistence.

Acceptance properties:

  (1) residency must not change the computation — a budget-constrained
      run (resident_blocks < P) is BITWISE-identical in values and in
      every algorithmic counter to the fully resident run, for PR/SSSP/CC
      on both the fused and host paths, and across warm streaming batches
      including deletes (only the spill-traffic counters may differ);
  (2) the budget is real — the resident set never exceeds it after the
      first admission and evictions actually happen;
  (3) save -> restore round-trips the fixpoint exactly and the warm
      verification pass reconverges to live-fixpoint parity in far fewer
      supersteps than a cold run;
  (4) pinned query epochs survive eviction.
"""
import os

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import algorithms as A
from repro.core import graph as G
from repro.core.engine import EngineConfig, StructureAwareEngine
from repro.ooc.store import SpillStore
from repro.stream import DeltaBatch, StreamingEngine, synthetic_stream

CFG = EngineConfig(t2=1e-9, width=4, block_size=128)
PROGS = {"pagerank": A.pagerank, "sssp": lambda: A.sssp(0), "cc": A.cc}

# counters that legitimately differ between budget and resident runs:
# the spill tier's own traffic (plus wall time); everything else in
# Metrics.as_dict is part of the algorithmic trajectory and must match
SPILL_FIELDS = ("spill_evictions", "bytes_spilled", "prefetch_hits",
                "prefetch_misses", "bytes_fetched", "prefetch_hit_rate",
                "wall_time_s")


def _assert_same_trajectory(res_full, res_budget):
    assert np.array_equal(res_full.values, res_budget.values)
    a, b = res_full.metrics.as_dict(), res_budget.metrics.as_dict()
    for k in a:
        if k in SPILL_FIELDS:
            continue
        assert a[k] == b[k], f"counter {k}: {a[k]} != {b[k]}"


# -- (1) bitwise parity under a residency budget -----------------------------
@settings(max_examples=6, deadline=None)
@given(prog=st.sampled_from(sorted(PROGS)),
       budget=st.integers(min_value=6, max_value=10),
       fused=st.booleans())
def test_budget_run_bitwise_identical(prog, budget, fused):
    g = G.powerlaw_graph(1500, avg_deg=6, seed=3, weighted=True)
    full = StructureAwareEngine(g, PROGS[prog](), CFG)
    assert full.plan.num_blocks > budget  # the budget must actually bind
    eng = StructureAwareEngine(
        g, PROGS[prog](),
        EngineConfig(**{**CFG.__dict__, "resident_blocks": budget}))
    _assert_same_trajectory(full.run(fused=fused), eng.run(fused=fused))
    assert eng.spill.spilled_blocks.size > 0  # it really ran out of core


def test_budget_warm_stream_bitwise_identical():
    """Warm streaming reconvergence (inserts + deletes, non-monotone
    re-heats included) under a budget matches the fully resident stream
    batch for batch — values bitwise, reports field for field."""
    g = G.powerlaw_graph(1200, avg_deg=5, seed=11, weighted=True)
    batches = synthetic_stream(g, 4, 60, seed=5, weighted=True,
                               delete_frac=0.3)
    cfg_b = EngineConfig(**{**CFG.__dict__, "resident_blocks": 7})
    se_full = StreamingEngine(g, A.sssp(0), CFG)
    se_budget = StreamingEngine(g, A.sssp(0), cfg_b)
    assert np.array_equal(se_full.values, se_budget.values)
    for batch in batches:
        rf = se_full.ingest(batch)
        rb = se_budget.ingest(batch)
        assert np.array_equal(se_full.values, se_budget.values)
        for f in ("iterations", "edges_processed", "dirty_blocks",
                  "vertices_reset", "converged", "blocks_retired",
                  "mean_dispatch_width"):
            assert getattr(rf, f) == getattr(rb, f), f
    assert se_budget.metrics.spill_evictions > 0
    m = se_budget.metrics.as_dict()
    assert 0.0 <= m["prefetch_hit_rate"] <= 1.0


# -- (2) the budget is enforced ----------------------------------------------
def test_residency_budget_enforced():
    g = G.powerlaw_graph(1500, avg_deg=6, seed=3)
    eng = StructureAwareEngine(
        g, A.pagerank(),
        EngineConfig(**{**CFG.__dict__, "resident_blocks": 7}))
    res = eng.run()
    assert res.metrics.converged
    spill = eng.spill
    assert int(spill.resident.sum()) <= 7
    assert res.metrics.spill_evictions > 0
    assert res.metrics.bytes_spilled > 0 and res.metrics.bytes_fetched > 0
    # pinned blocks (host-pad block 0 + the fused pad block) never spill
    assert spill.resident[0] and spill.resident[eng.pad_id]
    total = res.metrics.prefetch_hits + res.metrics.prefetch_misses
    assert total > 0
    assert res.metrics.prefetch_hit_rate == \
        res.metrics.prefetch_hits / total


def test_budget_too_small_rejected():
    g = G.powerlaw_graph(1500, avg_deg=6, seed=3)
    with pytest.raises(ValueError, match="resident_blocks"):
        StructureAwareEngine(
            g, A.pagerank(),
            EngineConfig(**{**CFG.__dict__, "resident_blocks":
                            CFG.width + 1}))


def test_disk_tier_roundtrip(tmp_path):
    """spill_dir + keep_host=False: payloads must survive a device-evict
    -> npz segment -> demand-fetch round trip with no host cache — the
    graphs-bigger-than-RAM configuration — and still land bitwise."""
    g = G.powerlaw_graph(1500, avg_deg=6, seed=3, weighted=True)
    full = StructureAwareEngine(g, A.pagerank(), CFG).run()
    eng = StructureAwareEngine(
        g, A.pagerank(),
        EngineConfig(**{**CFG.__dict__, "resident_blocks": 7,
                        "spill_dir": str(tmp_path)}))
    assert isinstance(eng.spill, SpillStore)
    assert not eng.spill.keep_host  # a directory means disk is the tier
    res = eng.run()
    _assert_same_trajectory(full, res)
    eng.spill.wait()
    segs = [f for f in os.listdir(tmp_path) if f.endswith(".npz")]
    assert segs, "evictions must have produced npz segments"


# -- (3) epoch persistence ---------------------------------------------------
def test_save_restore_fixpoint_roundtrip(tmp_path):
    g = G.powerlaw_graph(1200, avg_deg=5, seed=11, weighted=True)
    se = StreamingEngine(g, A.pagerank(), CFG)
    for batch in synthetic_stream(g, 2, 50, seed=5, weighted=True):
        se.ingest(batch)
    ck = se.save_epoch(str(tmp_path / "ck"))
    ck.wait()
    # verify=False: the checkpointed values come back BITWISE
    se_raw = StreamingEngine.restore(str(tmp_path / "ck"), A.pagerank(),
                                     CFG, verify=False)
    assert np.array_equal(se_raw.values, se.values)
    assert se_raw.epoch == se.epoch and se_raw.n == se.n
    # verify=True: the warm verification pass re-heats every block once
    # and must reconverge to live-fixpoint parity...
    se_warm = StreamingEngine.restore(str(tmp_path / "ck"), A.pagerank(),
                                      CFG)
    assert se_warm.initial_result.metrics.converged
    assert np.allclose(se_warm.values, se.values, atol=1e-6)
    # ...in far fewer supersteps than a cold start of the same graph
    cold = StructureAwareEngine(se.current_graph(), A.pagerank(),
                                CFG).run()
    warm_it = se_warm.initial_result.metrics.iterations
    assert warm_it < cold.metrics.iterations / 2, \
        f"warm restart took {warm_it} vs cold {cold.metrics.iterations}"
    # the restored engine is a full StreamingEngine: it can keep ingesting
    rep = se_warm.ingest(DeltaBatch.of(ins=[(1, 2), (3, 4)], dels=[]))
    assert rep.converged


def test_restore_under_budget_and_crossover(tmp_path):
    """A checkpoint written fully resident restores under an OOC budget
    (and vice versa) — persistence is independent of residency."""
    g = G.powerlaw_graph(1200, avg_deg=5, seed=11, weighted=True)
    cfg_b = EngineConfig(**{**CFG.__dict__, "resident_blocks": 7})
    se = StreamingEngine(g, A.sssp(0), cfg_b)  # written under a budget
    se.ingest(synthetic_stream(g, 1, 40, seed=6, weighted=True)[0])
    se.save_epoch(str(tmp_path / "ck")).wait()
    back_full = StreamingEngine.restore(str(tmp_path / "ck"), A.sssp(0),
                                        CFG, verify=False)
    back_ooc = StreamingEngine.restore(str(tmp_path / "ck"), A.sssp(0),
                                       cfg_b, verify=True)
    assert np.array_equal(back_full.values, se.values)
    assert back_ooc.engine.spill is not None
    assert np.allclose(back_ooc.values, se.values, atol=1e-6)


def test_checkpoint_edges_tuple_roundtrip(tmp_path):
    """The epoch checkpoint stores the COO truth as a TUPLE — the treedef
    round-trip (ckpt/manager) must bring it back as one, with dtypes."""
    from repro.ooc.snapshot import GraphCheckpoint
    g = G.powerlaw_graph(800, avg_deg=4, seed=2, weighted=True)
    se = StreamingEngine(g, A.pagerank(), CFG)
    se.save_epoch(str(tmp_path / "ck")).wait()
    tree, meta = GraphCheckpoint(str(tmp_path / "ck")).load()
    assert isinstance(tree["edges"], tuple) and len(tree["edges"]) == 3
    src, dst, w = tree["edges"]
    assert src.dtype == np.int64 and w.dtype == np.float32
    assert meta["n"] == g.n and meta["format"] == "graph-epoch-v1"
    gs, gd, gw = G.edges_of(se.current_graph())
    order = np.lexsort((dst, src))
    gorder = np.lexsort((gd, gs))
    assert np.array_equal(src[order], gs[gorder])
    assert np.array_equal(dst[order], gd[gorder])


# -- (4) pinned epochs survive eviction --------------------------------------
def test_pinned_epoch_survives_eviction():
    from repro.serve import Query, QueryService
    g = G.powerlaw_graph(900, avg_deg=5, seed=7, weighted=True)
    cfg_b = EngineConfig(**{**CFG.__dict__, "resident_blocks": 7})
    se = StreamingEngine(g, A.sssp(0), cfg_b)
    assert se.metrics.spill_evictions > 0 or \
        se.initial_result.metrics.spill_evictions > 0
    svc = QueryService(se, max_lanes=1)
    qid = svc.submit(Query(kind="sssp", source=3))
    # the pin is taken while blocks are spilled: it must already be a
    # materialized self-contained copy (no spilled holes)
    es = svc._pending[0].epoch_state
    assert es.preserved
    assert bool(np.asarray(es.ed.valid).sum()) and \
        int(np.asarray(es.ed.valid).sum()) == int(se.engine.edge_counts.sum())
    # ingest mutates + evicts underneath the pin; the answer must equal a
    # cold run on the PINNED (pre-ingest) graph
    frozen = se.current_graph()
    se.ingest(synthetic_stream(g, 1, 80, seed=9, weighted=True,
                               delete_frac=0.3)[0])
    r = [x for x in svc.run_pending() if x.query_id == qid][0]
    ref = StructureAwareEngine(frozen, A.sssp(3), CFG).run()
    assert np.array_equal(r.values, ref.values)
