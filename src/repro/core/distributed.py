"""shard_map execution of the structure-aware engine (paper Alg. 3's
master/mirror update, DESIGN.md §5).

Topology: the schedule width W = (devices on the data axis) x
(blocks-per-device). Each device runs its assigned blocks *sequentially*
(async semantics within the device, the paper's hot mode), then replicas are
reconciled once per call:

  * sum-combine programs (PageRank): blocks are disjoint across devices, so
    the update is an additive delta -> ``psum(values_local - values_in)``
    (Alg. 3 ``master <- mirror vertex update``);
  * min/max programs (SSSP/BFS/CC): ``pmin``/``pmax`` over replicas is exact
    because the combine is idempotent (``mirror <- master``).

PSDs are reconciled by masked ``pmax`` (each block is processed by at most
one device per call).

Cross-device visibility of hot updates happens at call boundaries — the same
relaxation PowerSwitch makes when it distributes its async mode. Vertex state
is replicated per device here (it is O(n) floats); for graphs whose state
exceeds a device, DESIGN.md §5 describes the sharded-state variant (boundary
deltas only).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.algorithms import VertexProgram
from repro.core.engine import (EngineConfig, StructureAwareEngine,
                               make_block_processor)
from repro.core.graph import Graph
from repro.core.partition import EdgeStorage

_NEG = np.float32(-1e38)


def default_mesh(axis: str = "data") -> Mesh:
    devs = np.array(jax.devices())
    return Mesh(devs, (axis,))


class DistributedEngine(StructureAwareEngine):
    """Drop-in engine with shard_map block processing over a mesh axis."""

    def __init__(self, graph: Graph, program: VertexProgram,
                 config: EngineConfig = EngineConfig(),
                 mesh: Mesh | None = None, axis: str = "data",
                 blocks_per_device: int | None = None):
        self.mesh = mesh or default_mesh(axis)
        self.axis = axis
        self.ndev = self.mesh.shape[axis]
        bpd = blocks_per_device or max(1, config.width // self.ndev)
        # shard_map dispatch is host-driven (fused=False): the mesh routing
        # happens per call, not inside a device-resident while_loop. The
        # adaptive active-set model is disabled: the dispatch width IS the
        # mesh (devices x blocks-per-device) — shrinking it would idle
        # devices, and the per-rank depth ladder would skew the round-robin
        # load balance this engine relies on. Sub-block tracking is pinned
        # flat too: the group-padded storages this engine dispatches over
        # have no masked sweep path (per-shard sub-block balance is the
        # follow-up the hierarchical plan sets up, not this engine).
        config = dataclasses.replace(config, width=self.ndev * bpd,
                                     fused=False, adaptive=False,
                                     subblocks=1)
        self.bpd = bpd
        super().__init__(graph, program, config)

    def run(self, max_iterations: int | None = None,
            fused: bool | None = None, warm=None):
        """shard_map dispatch is host-driven; the single-device fused chunk
        would silently ignore the mesh, so asking for it is an error (and
        warm streaming restarts are not distributed yet)."""
        if fused:
            raise ValueError(
                "DistributedEngine does not support the fused loop: "
                "dispatch is routed through shard_map per host call")
        if warm is not None:
            raise ValueError(
                "DistributedEngine does not support warm restarts yet")
        return super().run(max_iterations, fused=False)

    def _get_fn(self, store_key: str, sequential: bool):
        key = (store_key, sequential, "dist")
        if key in self._fns:
            return self._fns[key]
        store: EdgeStorage = getattr(self.plan, store_key)
        program, plan = self.program, self.plan
        c = plan.block_size
        process_one, process_iterated, gids = make_block_processor(
            program, store, self.aux, c, plan.n_live, plan.graph.n,
            self.config.use_pallas)
        t_inner = max(self.config.hot_inner_iters, 1) if sequential else 1
        bpd, axis, nblocks = self.bpd, self.axis, plan.num_blocks

        def device_run(values, psd, dmax, rows, ok):
            # local shapes: values (n,), psd/dmax (P,), rows (bpd,), ok (bpd,)
            values_in = values
            psd_in, dmax_in = psd, dmax

            def body(i, carry):
                values, psd, dmax, bmask = carry
                row = rows[i]
                base, new, psd_val, dmax_val = process_iterated(
                    values, row, t_inner)
                cur = lax.dynamic_slice(values, (base,), (c,))
                values = lax.dynamic_update_slice(
                    values, jnp.where(ok[i], new, cur), (base,))
                gid = gids[row]
                psd = jnp.where(ok[i], psd.at[gid].set(psd_val), psd)
                dmax = jnp.where(ok[i], dmax.at[gid].set(dmax_val), dmax)
                bmask = jnp.where(ok[i], bmask.at[gid].set(True), bmask)
                return values, psd, dmax, bmask

            bmask0 = jnp.zeros((nblocks,), bool)
            values_l, psd_l, dmax_l, bmask = lax.fori_loop(
                0, bpd, body, (values, psd, dmax, bmask0))

            if program.combine == "sum":
                values_out = values_in + lax.psum(values_l - values_in, axis)
            elif program.combine == "min":
                values_out = lax.pmin(values_l, axis)
            else:
                values_out = lax.pmax(values_l, axis)

            def reconcile(local, base_in):
                # psd/dmax carry a trailing (singleton) sub-block axis
                m = bmask[:, None] if local.ndim == 2 else bmask
                masked = jnp.where(m, local, _NEG)
                mx = lax.pmax(masked, axis)
                return jnp.where(mx > _NEG / 2, mx, base_in)

            return values_out, reconcile(psd_l, psd_in), \
                reconcile(dmax_l, dmax_in)

        smapped = shard_map(
            device_run, mesh=self.mesh,
            in_specs=(P(), P(), P(), P(self.axis), P(self.axis)),
            out_specs=(P(), P(), P()), check_rep=False)
        fn = jax.jit(smapped, donate_argnums=(0, 1, 2))
        self._fns[key] = fn
        return fn

    def _dispatch(self, values, psd, dmax, block_ids: np.ndarray,
                  sequential: bool, width: int | None = None):
        """Pad selection to (ndev * bpd) slots, round-robin across devices.
        ``width`` is accepted for base-class compatibility and ignored —
        the mesh fixes this engine's dispatch width (adaptive is pinned
        off in __init__)."""
        p, w = self.plan, self.ndev * self.bpd
        for store_key, cond in (("hot", block_ids < p.barrier_block),
                                ("cold", block_ids >= p.barrier_block)):
            ids = block_ids[cond]
            if ids.size == 0:
                continue
            offset = 0 if store_key == "hot" else p.barrier_block
            for at in range(0, ids.size, w):
                chunk = ids[at:at + w]
                rows = np.zeros(w, dtype=np.int32)
                ok = np.zeros(w, dtype=bool)
                # round-robin so each device's sequential sweep covers a
                # spread of priorities (straggler-friendly: equal bpd each)
                idx = np.arange(chunk.size)
                slot = (idx % self.ndev) * self.bpd + idx // self.ndev
                rows[slot] = (chunk - offset).astype(np.int32)
                ok[slot] = True
                fn = self._get_fn(store_key, sequential)
                with self.mesh:
                    values, psd, dmax = fn(values, psd, dmax,
                                           jnp.asarray(rows),
                                           jnp.asarray(ok))
        return values, psd, dmax
