"""Distributed tests run in subprocesses with forced host device counts
(so the main pytest process keeps its single real device)."""
import subprocess
import sys
import textwrap

import pytest


def run_sub(code: str, devices: int = 8, timeout: int = 900):
    env = {"XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
           "PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
           "JAX_PLATFORMS": "cpu"}
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env, cwd="/root/repo")
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_distributed_graph_engine_parity():
    run_sub("""
        import numpy as np
        from repro.core import algorithms as A, graph as G
        from repro.core.distributed import DistributedEngine
        from repro.core.engine import EngineConfig, StructureAwareEngine
        g = G.core_periphery_graph(6000, avg_deg=8, seed=1, chords=1)
        cfg = EngineConfig(t2=1e-9, width=8, block_size=256,
                           hot_inner_iters=4)
        local = StructureAwareEngine(g, A.pagerank(), cfg).run()
        dist = DistributedEngine(g, A.pagerank(), cfg,
                                 blocks_per_device=1).run()
        assert dist.metrics.converged
        assert np.allclose(local.values, dist.values, rtol=1e-4, atol=1e-8)
        # min-combine (SSSP) through pmin reconciliation
        g2 = G.powerlaw_graph(3000, 6, seed=3, weighted=True)
        l2 = StructureAwareEngine(g2, A.sssp(0), cfg).run()
        d2 = DistributedEngine(g2, A.sssp(0), cfg, blocks_per_device=1).run()
        assert np.allclose(np.minimum(l2.values, 1e18),
                           np.minimum(d2.values, 1e18), rtol=1e-5, atol=1e-3)
        print('PARITY OK')
    """)


def test_sharded_train_step_matches_single_device():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import configs
        from repro.data import SyntheticLM
        from repro.launch import sharding as shard_lib
        from repro.launch.mesh import make_host_mesh
        from repro.models import model as M
        from repro.optim import AdamWConfig, adamw_init
        from repro.train.step import make_train_step

        cfg = configs.reduced(configs.get('qwen3_14b'))
        data = SyntheticLM(cfg.vocab_size, 32, 8, seed=0)
        opt = AdamWConfig(peak_lr=1e-3, warmup_steps=1, total_steps=10)
        step = make_train_step(cfg, opt)

        params = M.init_params(cfg, jax.random.PRNGKey(0))
        state0 = {'params': params, 'opt': adamw_init(params)}
        s_ref, m_ref = jax.jit(step)(jax.tree.map(jnp.copy, state0),
                                     data.batch(0))

        mesh = make_host_mesh(model=2)  # (4, 2) data x model
        sspecs = shard_lib.state_specs(
            jax.eval_shape(lambda: state0), mesh)
        bspec = {'tokens': NamedSharding(mesh, P('data', None)),
                 'targets': NamedSharding(mesh, P('data', None))}
        state = jax.device_put(state0, sspecs)
        batch = jax.device_put(data.batch(0), bspec)
        jstep = jax.jit(step, in_shardings=(sspecs, bspec),
                        out_shardings=(sspecs, None))
        s_sh, m_sh = jstep(state, batch)
        np.testing.assert_allclose(float(m_ref['loss']),
                                   float(m_sh['loss']), rtol=1e-4)
        # Adam's first step is ~sign(g)*lr; sharded bf16 reductions can
        # flip signs of near-zero grads, so tolerate a few lr units.
        for a, b in zip(jax.tree.leaves(s_ref['params']),
                        jax.tree.leaves(s_sh['params'])):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=5e-2, atol=5e-3)
        print('SHARDED TRAIN OK')
    """)


def test_elastic_reshard_checkpoint():
    """Save under an 8-device mesh, restore under a 4-device mesh."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.ckpt import CheckpointManager

        devs = np.array(jax.devices())
        mesh8 = Mesh(devs.reshape(4, 2), ('data', 'model'))
        mesh4 = Mesh(devs[:4].reshape(2, 2), ('data', 'model'))
        tree = {'w': jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
        sh8 = {'w': NamedSharding(mesh8, P('data', 'model'))}
        sh4 = {'w': NamedSharding(mesh4, P('data', 'model'))}
        t8 = jax.device_put(tree, sh8)
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, async_write=False)
            mgr.save(1, t8)
            restored, _ = mgr.restore(shardings=sh4)
            np.testing.assert_array_equal(np.asarray(restored['w']),
                                          np.asarray(tree['w']))
            assert restored['w'].sharding == sh4['w']
        print('RESHARD OK')
    """)


def test_ef_compressed_psum_in_shard_map():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.optim import ef_compress_psum

        mesh = Mesh(np.array(jax.devices()), ('pod',))
        def f(g, r):
            return ef_compress_psum(g, r, 'pod')
        g = jnp.arange(8 * 16, dtype=jnp.float32).reshape(8, 16) / 100.
        r = jnp.zeros((8, 16))
        sm = shard_map(f, mesh=mesh, in_specs=(P('pod'), P('pod')),
                       out_specs=(P('pod'), P('pod')), check_rep=False)
        out, resid = jax.jit(sm)(g, r)
        want = jnp.broadcast_to(g.mean(0, keepdims=True), g.shape)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-3)
        print('EF PSUM OK')
    """)


def test_dryrun_plumbing_small_mesh():
    """The dry-run machinery end-to-end on a (2,2,2) toy pod mesh."""
    run_sub("""
        import jax
        from repro.launch import dryrun as dr
        from repro import configs
        from repro.models.config import ShapeConfig, SHAPES
        import dataclasses
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 2, 2), ('pod', 'data', 'model'))
        # tiny shape grid against the reduced config
        SHAPES['t_train'] = ShapeConfig('t_train', 64, 8, 'train')
        SHAPES['t_dec'] = ShapeConfig('t_dec', 64, 8, 'decode')
        cfg = configs.reduced(configs.get('granite_moe_3b_a800m'))
        import repro.configs as C
        orig = C.get
        C.get = lambda name: cfg
        try:
            for shp in ('t_train', 't_dec'):
                r = dr.lower_cell('granite_moe_3b_a800m', shp, mesh, 'toy')
                assert r['status'] == 'ok', r
                assert r['flops'] > 0
                assert r['peak_bytes'] > 0
        finally:
            C.get = orig
        g = dr.lower_graph_cell(mesh, 'toy', n=65536, block_size=4096,
                                e_cap=8192)
        assert g['status'] == 'ok' and g['collective_bytes'] > 0
        print('DRYRUN PLUMBING OK')
    """, devices=8)
