"""Architecture config schema + input-shape sets (assigned grid)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    qk_norm: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0  # routed-expert width (fine-grained MoE)
    capacity_factor: float = 1.25
    # --- SSM (mamba2 SSD) ---
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_conv_width: int = 4
    # --- hybrid (hymba): parallel attn + ssm heads in every layer ---
    parallel_ssm: bool = False
    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    # --- vlm stub (phi-3-vision): patch embeddings fill the first slots ---
    num_patches: int = 0
    # --- audio stub (whisper): frame embeddings replace encoder tokens ---
    frame_input: bool = False
    dtype: str = "bfloat16"
    # vocab padding multiple: keeps the embedding/vocab dim divisible by any
    # mesh "model" axis (padded ids are never targets)
    pad_vocab_to: int = 2048
    # activation-checkpoint policy for the layer scan (perf lever, §Perf):
    #   "full"      — recompute everything in backward (min memory)
    #   "save_dots" — save matmul outputs, recompute elementwise only
    #   "none"      — save all residuals (max memory, min recompute)
    remat_policy: str = "full"
    # EXACT structural padding (perf levers, §Perf): padded q heads have
    # zero wo rows, padded kv heads zero wk/wv columns, padded experts are
    # never routed — all provably inert and gradient-stable (see §Perf).
    # They exist to make the head/expert axes divisible by the mesh "model"
    # axis, eliminating GSPMD resharding storms.
    pad_q_heads_to: int = 0
    pad_kv_heads_to: int = 0
    pad_experts_to: int = 0
    # §Perf levers (off = paper-faithful baseline):
    # cast f32 master weights to compute dtype ONCE outside the layer scan,
    # so GSPMD gathers bf16 (half the collective bytes) instead of f32
    cast_weights_once: bool = False
    # shard the input embedding on d_model instead of vocab (untied archs):
    # token lookup becomes local instead of an all-gather of the table
    embed_d_shard: bool = False
    # pin q/k/v/o activation shardings in attention to
    # (batch_axes, None, "model", None) — stops GSPMD's seq-resharding
    # wander inside the chunked-attention loops (launcher supplies axes)
    shard_attn: bool = False

    @property
    def q_heads_eff(self) -> int:
        return max(self.num_heads, self.pad_q_heads_to)

    @property
    def kv_heads_eff(self) -> int:
        return max(self.num_kv_heads, self.pad_kv_heads_to)

    @property
    def experts_eff(self) -> int:
        return max(self.num_experts, self.pad_experts_to)

    @property
    def vocab_padded(self) -> int:
        m = max(self.pad_vocab_to, 1)
        return -(-self.vocab_size // m) * m

    @property
    def resolved_head_dim(self) -> int:
        if self.num_heads == 0:
            return 0
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def has_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def has_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM state keeps decode O(1)-ish)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline math)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        attn = d * hd * (self.num_heads * 2 + self.num_kv_heads * 2)
        mlp = 3 * d * f if f else 0
        moe = 0
        if self.num_experts:
            fe = self.moe_d_ff or f
            moe = (self.num_experts * 3 * d * fe
                   + self.num_shared_experts * 3 * d * fe
                   + d * self.num_experts)
            mlp = 0
        ssm = 0
        if self.has_ssm:
            d_in = self.ssm_heads * self.ssm_head_dim
            n = self.ssm_state
            ssm = d * (2 * d_in + 2 * n + self.ssm_heads) + d_in * d
        per_layer = 2 * d + mlp + moe
        if self.has_attention:
            per_layer += attn
        if self.has_ssm:
            per_layer += ssm
        total = self.num_layers * per_layer
        if self.is_encdec:  # encoder self-attn+mlp, decoder gets cross-attn
            total += self.encoder_layers * (2 * d + attn + 3 * d * f)
            total += self.num_layers * attn  # cross-attention
        total += self.vocab_padded * d * (1 if self.tie_embeddings else 2) + d
        return total

    def active_param_count(self) -> int:
        """MoE: params touched per token (6*N_active*D)."""
        if not self.num_experts:
            return self.param_count()
        fe = self.moe_d_ff or self.d_ff
        active_moe = ((self.experts_per_token + self.num_shared_experts)
                      * 3 * self.d_model * fe + self.d_model
                      * self.num_experts)
        total_moe = (self.num_experts + self.num_shared_experts) * 3 \
            * self.d_model * fe + self.d_model * self.num_experts
        return self.param_count() - self.num_layers * (total_moe - active_moe)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
