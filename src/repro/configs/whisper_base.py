"""whisper-base [audio]: enc-dec 6L+6L d=512 8H ff=2048 vocab 51865; conv/mel
frontend STUB — input_specs provides precomputed frame embeddings for the
encoder. [arXiv:2212.04356; unverified]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base", family="audio",
    num_layers=6, d_model=512, num_heads=8, num_kv_heads=8,
    d_ff=2048, vocab_size=51865,
    encoder_layers=6, frame_input=True,
)
