"""Optional-hypothesis shim for the property tests.

When the real ``hypothesis`` package is installed (``pip install -r
requirements-dev.txt``) this module re-exports it unchanged and the suite
gets full randomized property testing with shrinking. When it is absent,
``@given`` degrades to running the test body on a small deterministic set of
examples drawn from the declared strategies (bounds first, then seeded
pseudo-random draws), so the tier-1 suite still exercises every property.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import numpy as np

    class _Strategy:
        """A draw function plus the deterministic boundary examples that are
        always exercised before any random draws."""

        def __init__(self, draw, boundary=()):
            self._draw = draw
            self.boundary = tuple(boundary)

        def draw(self, rng):
            return self._draw(rng)

    class _StrategiesModule:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)),
                boundary=(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(
                lambda rng: elements[int(rng.integers(len(elements)))],
                boundary=elements[:1])

        @staticmethod
        def floats(min_value, max_value, **_):
            lo, hi = float(min_value), float(max_value)
            return _Strategy(lambda rng: float(rng.uniform(lo, hi)),
                             boundary=(lo, hi))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(2)),
                             boundary=(False, True))

    st = _StrategiesModule()

    _FALLBACK_EXAMPLES = 5  # examples per test when hypothesis is absent

    def given(**strategies):
        def deco(fn):
            # NOT functools.wraps: pytest would introspect the wrapped
            # signature and treat the strategy kwargs as fixtures.
            def wrapper(*args, **kwargs):
                rng = np.random.default_rng(0)
                n = min(getattr(fn, "_max_examples", _FALLBACK_EXAMPLES),
                        _FALLBACK_EXAMPLES)
                cases = []
                # one all-boundary case (first bound of every strategy), then
                # seeded random draws for the rest
                cases.append({k: s.boundary[0] if s.boundary else s.draw(rng)
                              for k, s in strategies.items()})
                while len(cases) < n:
                    cases.append({k: s.draw(rng)
                                  for k, s in strategies.items()})
                for case in cases:
                    try:
                        fn(*args, **case, **kwargs)
                    except Exception as e:
                        raise AssertionError(
                            f"falsifying example (hypothesis-compat): "
                            f"{case}") from e
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    def settings(max_examples=_FALLBACK_EXAMPLES, **_):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
