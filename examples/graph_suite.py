"""Run all five paper algorithms (PR, CC, SSSP, BFS, BC) on three graph
families through both engines and print the comparison table.

    PYTHONPATH=src python examples/graph_suite.py [--n 20000]
"""
import argparse

import numpy as np

from repro.core import algorithms as A
from repro.core import graph as G
from repro.core.baseline import BaselineEngine
from repro.core.engine import EngineConfig, StructureAwareEngine, betweenness


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20000)
    args = ap.parse_args()
    n = args.n
    graphs = {
        "powerlaw": G.powerlaw_graph(n, avg_deg=8, seed=1, weighted=True),
        "core-periphery": G.core_periphery_graph(n, avg_deg=8, seed=1,
                                                 chords=1, weighted=True),
        "road-like": G.uniform_graph(n // 4, deg=4, seed=2, weighted=True),
    }
    cfg = EngineConfig(t2=1e-8, width=16, block_size=512)
    print(f"{'graph':16s}{'algo':10s}{'base-loads':>11s}{'sa-loads':>9s}"
          f"{'base-upd':>10s}{'sa-upd':>9s}{'agree':>6s}")
    for gname, g in graphs.items():
        for aname, prog in [("pagerank", A.pagerank()), ("cc", A.cc()),
                            ("sssp", A.sssp(0)), ("bfs", A.bfs(0))]:
            base = BaselineEngine(g, prog, cfg, frontier=False).run()
            sa = StructureAwareEngine(g, prog, cfg).run()
            # both engines stop within t2 of the fixpoint, not at it:
            # compare at the tolerance t2 guarantees (hub ranks ~1e-2)
            ok = np.allclose(np.minimum(base.values, 1e18),
                             np.minimum(sa.values, 1e18),
                             rtol=1e-3, atol=1e-5)
            print(f"{gname:16s}{aname:10s}{base.metrics.block_loads:>11d}"
                  f"{sa.metrics.block_loads:>9d}{base.metrics.updates:>10d}"
                  f"{sa.metrics.updates:>9d}{str(ok):>6s}")
        bc_sa, m_sa = betweenness(g, [0, 1], cfg, structure_aware=True)
        bc_b, m_b = betweenness(g, [0, 1], cfg, structure_aware=False)
        ok = np.allclose(bc_sa, bc_b, rtol=1e-4, atol=1e-6)
        print(f"{gname:16s}{'bc':10s}{m_b.block_loads:>11d}"
              f"{m_sa.block_loads:>9d}{m_b.updates:>10d}"
              f"{m_sa.updates:>9d}{str(ok):>6s}")


if __name__ == "__main__":
    main()
