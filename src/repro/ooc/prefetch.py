"""Activity-directed residency policy (pure numpy, no device state).

The engine already predicts its own future: the host
:class:`repro.core.schedule.Scheduler` is kept decision-identical to the
fused device select (the ``@decision_identical`` contract on
``make_device_select``, plus a property test), so one numpy ``select``
call tells the spill tier exactly which blocks the imminent superstep
will read. These helpers turn that prediction plus the PSD/calm activity
state into residency decisions:

  * :func:`demand_blocks` — the block set a superstep touches (scheduled
    hot + cold slots, plus the pad block every padded slot computes);
  * :func:`rank_fetch_candidates` — non-resident blocks worth staging
    ahead of need, hottest PSD first (UNSEEN re-heats sort to the front,
    exactly the blocks the next wave must sweep);
  * :func:`rank_victims` — eviction order: most-calm first, then lowest
    PSD, then block id. Retired/calm blocks — the paper's cold partition
    — ARE the spill set; ``retired_only`` restricts a speculative swap to
    blocks the active set has already abandoned, while a demand eviction
    (must make room NOW) takes the calmest victim unconditionally.

All ranking is deterministic (stable orders, id tie-breaks) so a
budget-constrained run makes the same residency decisions every time —
every helper carries @deterministic (repro.analysis.contracts), which
puts this module under the nondeterminism lint (RA004: no clocks, no
unseeded randomness).
"""
from __future__ import annotations

import numpy as np

from repro.analysis.contracts import deterministic
from repro.core.schedule import Selection


@deterministic
def demand_blocks(sel: Selection, pad_id: int) -> np.ndarray:
    """Unique block ids the imminent superstep will read: every scheduled
    hot/cold slot plus ``pad_id`` (slots beyond the take counts carry the
    pad block and the fused sweeps still compute it)."""
    return np.unique(np.concatenate(
        [sel.hot_ids.astype(np.int64), sel.cold_ids.astype(np.int64),
         np.array([pad_id], dtype=np.int64)]))


@deterministic
def fold_calm(calm: np.ndarray | None) -> np.ndarray | None:
    """(P, S) sub-block calm counters -> block calm: a block is only as
    retired as its least-calm sub-block (matches the engine's
    ``_active_count`` definition of a live block)."""
    if calm is None:
        return None
    calm = np.asarray(calm)
    return calm.min(axis=-1) if calm.ndim == 2 else calm


@deterministic
def rank_fetch_candidates(psd_blk: np.ndarray, resident: np.ndarray,
                          floor: float) -> np.ndarray:
    """Non-resident blocks worth prefetching, hottest first. Blocks under
    the scheduler's pruning floor are excluded — they cannot be scheduled
    until something re-arms them, and fetching them would only churn the
    budget. Ties break by block id (stable sort on -psd)."""
    cand = np.flatnonzero(~resident & (psd_blk >= floor))
    return cand[np.argsort(-psd_blk[cand], kind="stable")]


@deterministic
def rank_victims(psd_blk: np.ndarray, calm_blk: np.ndarray | None,
                 resident: np.ndarray, protect: np.ndarray,
                 retire_after: int, retired_only: bool) -> np.ndarray:
    """Eviction candidates among the resident, unprotected blocks, coldest
    first: most consecutive calm supersteps, then lowest PSD, then block
    id. With ``retired_only`` only blocks past the retire threshold
    qualify (speculative prefetch swaps must not evict the active set);
    without it the calmest block goes regardless (demand evictions must
    make room). ``protect`` is a (P,) bool mask (demand set + pins)."""
    cand = np.flatnonzero(resident & ~protect)
    if calm_blk is None:
        return cand[np.argsort(psd_blk[cand], kind="stable")]
    if retired_only:
        cand = cand[calm_blk[cand] >= retire_after]
    # np.lexsort: last key is primary -> calm desc, then psd asc, then the
    # original (ascending id) order for full ties
    return cand[np.lexsort((psd_blk[cand], -calm_blk[cand]))]
