"""Per-arch smoke tests (reduced configs) + model-level invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as M
from repro.models import moe as moe_lib
from repro.models.config import ShapeConfig
from repro.optim import AdamWConfig
from repro.train.step import make_train_step

TRAIN = ShapeConfig("smoke_train", 64, 2, "train")


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_arch_smoke_forward_and_train(name):
    cfg = configs.reduced(configs.get(name))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = configs.input_specs(cfg, TRAIN, concrete=True)
    logits, aux = M.forward(params, cfg, batch)
    assert logits.shape == (2, 64, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    # one optimizer step must run and keep everything finite
    step = make_train_step(cfg, AdamWConfig(total_steps=10))
    state = {"params": params,
             "opt": {"m": jax.tree.map(lambda p: jnp.zeros(p.shape), params),
                     "v": jax.tree.map(lambda p: jnp.zeros(p.shape), params),
                     "step": jnp.zeros((), jnp.int32)}}
    state, metrics = jax.jit(step)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    flat = jax.tree.leaves(state["params"])
    assert all(bool(jnp.isfinite(x.astype(jnp.float32)).all()) for x in flat)


@pytest.mark.parametrize("name", ["llama3p2_1b", "mamba2_2p7b",
                                  "hymba_1p5b", "whisper_base"])
def test_decode_matches_forward(name):
    """Prefill+decode token-by-token must equal the full-sequence forward
    (cache correctness across attention / SSM / hybrid / enc-dec)."""
    cfg = configs.reduced(configs.get(name))
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    s, b = 32, 2
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s), np.int32))
    batch = {"tokens": tokens}
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, s, cfg.d_model)).astype(np.float32),
            dtype=jnp.dtype(cfg.dtype))
    logits_full, _ = M.forward(params, cfg, batch, remat=False)

    half = s // 2
    pre_batch = {"tokens": tokens[:, :half]}
    if cfg.is_encdec:
        pre_batch["frames"] = batch["frames"]
    cache = M.init_cache(cfg, b, s, enc_seq=s)
    lg, cache = M.prefill(params, cfg, pre_batch, cache)
    np.testing.assert_allclose(
        lg.astype(np.float32), logits_full[:, half - 1].astype(np.float32),
        rtol=5e-2, atol=5e-2)
    # feed the TRUE next tokens and compare stepwise logits
    for t in range(half, s):
        lg, cache = M.decode_step(params, cfg, tokens[:, t:t + 1], cache)
        if t < s - 1:
            np.testing.assert_allclose(
                lg.astype(np.float32), logits_full[:, t].astype(np.float32),
                rtol=5e-2, atol=5e-2)


def test_moe_routing_invariants():
    rng = np.random.default_rng(0)
    d, e, fe, k = 16, 8, 8, 2
    x = jnp.asarray(rng.normal(size=(2, 32, d)).astype(np.float32))
    params = {
        "router": jnp.asarray(rng.normal(size=(d, e)).astype(np.float32)),
        "w_gate": jnp.asarray(rng.normal(size=(e, d, fe)).astype(np.float32)),
        "w_up": jnp.asarray(rng.normal(size=(e, d, fe)).astype(np.float32)),
        "w_down": jnp.asarray(
            rng.normal(size=(e, fe, d)).astype(np.float32)) * 0.1,
    }
    y, aux = moe_lib.moe_ffn(x, params, num_experts=e, top_k=k,
                             capacity_factor=8.0)  # no drops at cf=8
    assert y.shape == x.shape
    # every token got exactly k assignments
    assert float(aux["expert_load"].sum()) == 2 * 32 * k
    # lb_loss >= 1 (equals E * sum(me*ce) with min at uniform = 1)
    assert float(aux["lb_loss"]) >= 0.99


def test_moe_capacity_drops_are_bounded():
    rng = np.random.default_rng(1)
    d, e, k = 8, 4, 2
    x = jnp.asarray(rng.normal(size=(1, 64, d)).astype(np.float32))
    params = {
        "router": jnp.zeros((d, e), jnp.float32),  # uniform router
        "w_gate": jnp.asarray(rng.normal(size=(e, d, 8)).astype(np.float32)),
        "w_up": jnp.asarray(rng.normal(size=(e, d, 8)).astype(np.float32)),
        "w_down": jnp.asarray(rng.normal(size=(e, 8, d)).astype(np.float32)),
    }
    y, _ = moe_lib.moe_ffn(x, params, num_experts=e, top_k=k,
                           capacity_factor=1.0)
    assert bool(jnp.isfinite(y).all())


def test_expert_rebalance_plan():
    """Structure-aware expert scheduling: hot experts spread across shards."""
    act = np.array([100.0, 90, 80, 70, 1, 1, 1, 1])
    perm = moe_lib.rebalance_plan(act, num_shards=4)
    # each shard gets 2 experts; the 4 hot ones must land on 4 DIFFERENT
    # shards
    shard_of = perm // 2
    assert len(set(shard_of[:4])) == 4


def test_vocab_padding_divisible():
    for name in configs.ARCH_NAMES:
        cfg = configs.get(name)
        assert cfg.vocab_padded % 256 == 0
        assert cfg.vocab_padded >= cfg.vocab_size


def test_param_count_sane():
    # published sizes within ~20% (analytic count, padded vocab)
    expect = {"yi_6b": 6e9, "llama3p2_1b": 1.2e9, "qwen3_14b": 14e9,
              "mistral_nemo_12b": 12e9, "deepseek_moe_16b": 16e9,
              "mamba2_2p7b": 2.7e9}
    for name, n in expect.items():
        got = configs.get(name).param_count()
        assert 0.7 * n < got < 1.45 * n, (name, got)


@pytest.mark.parametrize("name,pad", [
    ("qwen3_14b", dict(pad_q_heads_to=8, pad_kv_heads_to=4)),
    ("granite_moe_3b_a800m", dict(pad_experts_to=6)),
])
def test_structural_padding_is_exact(name, pad):
    """§Perf levers: zero-padded heads/experts change NOTHING numerically
    (padded q heads have zero wo rows; padded experts are never routed)."""
    cfg = configs.reduced(configs.get(name))
    cfgp = dataclasses.replace(cfg, **pad)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32), np.int32))
    p0 = M.init_params(cfg, jax.random.PRNGKey(0))
    pp = M.init_params(cfgp, jax.random.PRNGKey(0))
    dh = cfg.resolved_head_dim
    # inject the base weights into the padded layout
    if "attn" in p0["layers"]:
        a, b = p0["layers"]["attn"], pp["layers"]["attn"]
        rq, rkv = cfg.num_heads * dh, cfg.num_kv_heads * dh
        b["wq"] = b["wq"].at[:, :, :rq].set(a["wq"])
        b["wk"] = b["wk"].at[:, :, :rkv].set(a["wk"])
        b["wv"] = b["wv"].at[:, :, :rkv].set(a["wv"])
        b["wo"] = b["wo"].at[:, :rq, :].set(a["wo"])
        for kk in ("q_norm", "k_norm"):
            if kk in a:
                b[kk] = a[kk]
    if "moe" in p0["layers"]:
        a, b = p0["layers"]["moe"], pp["layers"]["moe"]
        e = cfg.num_experts
        for kk in ("w_gate", "w_up", "w_down"):
            b[kk] = b[kk].at[:, :e].set(a[kk])
        b["router"] = b["router"].at[:, :, :e].set(a["router"])
        for kk in [x for x in a if x.startswith("shared")]:
            b[kk] = a[kk]
    for kk in ("embed", "ln_f", "lm_head"):
        if kk in p0:
            pp[kk] = p0[kk]
    for kk in ("ln1", "ln2", "mlp", "ssm"):
        if kk in p0["layers"]:
            pp["layers"][kk] = p0["layers"][kk]
    l0, _ = M.forward(p0, cfg, {"tokens": tokens}, remat=False)
    l1, _ = M.forward(pp, cfgp, {"tokens": tokens}, remat=False)
    np.testing.assert_array_equal(np.asarray(l0, np.float32),
                                  np.asarray(l1, np.float32))


def test_expert_rebalancing_runtime():
    """The paper's dynamic repartitioning applied to experts at runtime:
    permuting experts+router is function-preserving AND reduces the
    predicted EP-shard imbalance under skewed routing."""
    from repro.train.expert_balance import (ExpertRebalancer,
                                            permute_expert_axis)
    cfg = configs.reduced(configs.get("granite_moe_3b_a800m"))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    # skew the router: experts 0..1 get huge logits -> hot
    router = params["layers"]["moe"]["router"]
    params["layers"]["moe"]["router"] = router.at[:, :, :2].add(3.0)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 64), np.int32))
    logits0, aux = M.forward(params, cfg, {"tokens": tokens}, remat=False)
    load = np.asarray(aux["expert_load"], np.float64)
    assert load[:2].sum() > load[2:].sum()  # routing is skewed

    reb = ExpertRebalancer(num_experts=cfg.num_experts, num_shards=2,
                           interval=1)
    perm = reb.observe(load, step=1)
    assert perm is not None  # skew big enough to justify a move
    act, _ = __import__("repro.models.moe", fromlist=["m"]).expert_activity(
        np.zeros(cfg.num_experts), load)
    before = reb.shard_imbalance(act)
    after = reb.shard_imbalance(act[np.argsort(perm)])
    assert after < before  # hot experts spread across shards

    new_params = permute_expert_axis(params, perm)
    logits1, _ = M.forward(new_params, cfg, {"tokens": tokens}, remat=False)
    np.testing.assert_array_equal(np.asarray(logits0, np.float32),
                                  np.asarray(logits1, np.float32))
