"""Pallas TPU kernels (+ jnp oracles) for the framework's compute hot spots.

spmv             : partition edge-block segment-sum (graph engine hot spot)
flash_attention  : causal GQA online-softmax attention (LM prefill hot spot)
ref              : pure-jnp oracles
ops              : jit'd dispatch (interpret on CPU, Mosaic on TPU)
"""
from repro.kernels import ops, ref  # noqa: F401
