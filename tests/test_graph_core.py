"""Unit + property tests: degrees (Eq. 1/2), partitioning (Alg. 1)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import degrees, graph as G
from repro.core.partition import build_plan


def test_degree_function_eq1():
    # 0 -> 1, 0 -> 2, 1 -> 2 : out = [2,1,0], in = [0,1,2]
    g = G.from_edges(3, [0, 0, 1], [1, 2, 2])
    d = degrees.degree_function(g, alpha=0.5)
    assert np.allclose(d, [2 + 0.0, 1 + 0.5, 0 + 1.0])


def test_degree_function_alpha_bounds():
    g = G.from_edges(2, [0], [1])
    with pytest.raises(ValueError):
        degrees.degree_function(g, alpha=0.0)


def test_active_degree_eq2_hand():
    # two vertices, one edge 0 -> 1, alpha = 1: D = [1, 1], Dmax = 1
    # AD(v) = D(v) + sum_nbr D / (sqrt(Dmax) * D(v)) = 1 + 1/1 = 2
    g = G.from_edges(2, [0], [1])
    ad = degrees.active_degree(g, alpha=1.0)
    assert np.allclose(ad, [2.0, 2.0])


def test_dead_vertices_have_zero_ad():
    g = G.from_edges(4, [0, 1], [1, 0])  # 2 and 3 are isolated
    ad = degrees.active_degree(g)
    assert ad[2] == 0.0 and ad[3] == 0.0 and ad[0] > 0


def test_suggest_alpha_regimes():
    road = G.uniform_graph(2000, deg=4, seed=0)
    social = G.powerlaw_graph(2000, avg_deg=8, seed=0)
    a_road = degrees.suggest_alpha(road)
    a_social = degrees.suggest_alpha(social)
    assert 0.5 < a_road < a_social < 1.0  # paper: road->0.5, weibo->1


@given(n=st.integers(50, 400), avg=st.integers(2, 8),
       seed=st.integers(0, 10))
@settings(max_examples=15, deadline=None)
def test_partition_plan_invariants(n, avg, seed):
    g = G.powerlaw_graph(n, avg_deg=avg, seed=seed)
    plan = build_plan(g, block_size=64)
    # every vertex appears exactly once in the permutation
    assert np.array_equal(np.sort(plan.order), np.arange(n))
    # AD is non-increasing over the live prefix
    live_ad = plan.ad[:plan.n_live]
    assert np.all(np.diff(live_ad) <= 1e-9)
    # dead tail has zero AD
    assert np.all(plan.ad[plan.n_live:] == 0)
    # hot storage rows are the blocks before the barrier
    assert np.array_equal(plan.hot.block_ids,
                          np.arange(plan.barrier_block))
    # padded edge storage is lane-aligned and mask-consistent
    for store in (plan.hot, plan.cold):
        if store.num_blocks:
            assert store.capacity % 128 == 0
            assert np.array_equal(store.valid.sum(1), store.edges)
    # block edge slices cover ALL in-edges of live vertices exactly once
    total = int(plan.hot.edges.sum() + plan.cold.edges.sum())
    assert total == plan.graph.m


@given(n=st.integers(50, 400), avg=st.integers(2, 8),
       seed=st.integers(0, 10))
@settings(max_examples=15, deadline=None)
def test_unified_tiled_storage_invariants(n, avg, seed):
    g = G.powerlaw_graph(n, avg_deg=avg, seed=seed)
    plan = build_plan(g, block_size=64)
    u = plan.unified
    # lane-aligned tiles, per-block ownership covers every in-edge once
    assert u.tile % 128 == 0
    assert u.num_blocks == plan.num_blocks
    assert int(u.edges.sum()) == plan.graph.m
    for b in range(plan.num_blocks):
        t0, tc = int(u.tile_start[b]), int(u.tile_cnt[b])
        assert tc == -(-int(u.edges[b]) // u.tile)
        assert int(u.valid[t0:t0 + tc].sum()) == int(u.edges[b])
    # group storages and unified storage agree on per-block edge counts
    grouped = np.concatenate([plan.hot.edges, plan.cold.edges])
    assert np.array_equal(grouped, u.edges)


def test_block_bytes_positive(core_periphery_small):
    plan = build_plan(core_periphery_small, block_size=256)
    for b in range(plan.num_blocks):
        assert plan.block_bytes(b) > 0
