"""Mixture-of-Experts FFN: token-choice top-k, sort-based capacity dispatch.

Design (DESIGN.md §5): each batch row is a dispatch group (groups shard over
("pod","data")), experts shard over "model" (EP). Within a group the
assignment is sorted by expert id, positions are computed with a cumsum, and
tokens scatter into a dense (E, C, D) buffer — the expert matmuls are then
plain einsums and GSPMD inserts exactly one all-to-all each way for the
group->expert resharding. Capacity C = S*k/E * capacity_factor; overflow
tokens drop (standard Switch semantics) but keep their shared-expert and
residual paths.

Includes the load-balance aux loss (Switch/DeepSeek form) and router z-loss.

The *structure-aware expert schedule* (the paper's technique applied beyond
paper, see DESIGN.md §4) lives in ``expert_activity`` / ``rebalance_plan``:
expert load is power-law-skewed exactly like vertex degree, so hot experts
are re-binned across EP shards by an AD-style activity estimate.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _group_dispatch(x, gates, eidx, num_experts: int, capacity: int):
    """x: (S, D); gates: (S, k); eidx: (S, k) -> (buf (E, C, D), meta)."""
    s, d = x.shape
    k = gates.shape[-1]
    flat_e = eidx.reshape(-1)  # (S*k,)
    order = jnp.argsort(flat_e)  # stable by expert id
    e_sorted = flat_e[order]
    tok_sorted = order // k
    gate_sorted = gates.reshape(-1)[order]
    counts = jnp.bincount(flat_e, length=num_experts)
    seg_start = jnp.cumsum(counts) - counts
    pos = jnp.arange(s * k) - seg_start[e_sorted]
    # out-of-capacity assignments drop (scatter mode='drop')
    buf = jnp.zeros((num_experts, capacity, d), x.dtype)
    buf = buf.at[e_sorted, pos].set(x[tok_sorted], mode="drop")
    return buf, (e_sorted, pos, tok_sorted, gate_sorted)


def _group_combine(out_buf, meta, s: int):
    e_sorted, pos, tok_sorted, gate_sorted = meta
    d = out_buf.shape[-1]
    # gather expert outputs back (OOB positions -> 0 via fill)
    vals = out_buf.at[e_sorted, pos].get(mode="fill", fill_value=0.0)
    vals = vals * gate_sorted[:, None].astype(vals.dtype)
    out = jnp.zeros((s, d), out_buf.dtype)
    return out.at[tok_sorted].add(vals)


def moe_ffn(x, params, *, num_experts: int, top_k: int,
            capacity_factor: float = 1.25, norm_topk: bool = True,
            num_real_experts: int | None = None):
    """x: (B, S, D). params: router (D, E), w_gate/w_up (E, D, Fe),
    w_down (E, Fe, D), optional shared_{gate,up,down}.
    ``num_experts`` may exceed ``num_real_experts`` (structural padding for
    EP divisibility): padded experts are masked out of routing entirely.
    Returns (y, aux) with aux = {lb_loss, z_loss, expert_load (E,)}."""
    b, s, d = x.shape
    real = num_real_experts or num_experts
    logits = (x.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    if real < num_experts:
        pad_mask = jnp.arange(num_experts) >= real
        logits = jnp.where(pad_mask[None, None], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)  # (B, S, E)
    gates, eidx = jax.lax.top_k(probs, top_k)  # (B, S, k)
    if norm_topk:
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    capacity = max(int(s * top_k / real * capacity_factor), top_k)

    def per_group(xg, gg, eg):
        buf, meta = _group_dispatch(xg, gg, eg, num_experts, capacity)
        h = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
        u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
        ob = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u,
                        params["w_down"])
        return _group_combine(ob, meta, s)

    y = jax.vmap(per_group)(x, gates.astype(x.dtype), eidx)

    if "shared_gate" in params:
        h = jax.nn.silu(x @ params["shared_gate"]) * (x @ params["shared_up"])
        y = y + h @ params["shared_down"]

    # aux losses (computed in f32 on router stats)
    me = jnp.mean(probs, axis=(0, 1))  # mean prob per expert
    load1 = jnp.zeros(num_experts).at[eidx.reshape(-1)].add(1.0)
    ce = load1 / jnp.maximum(load1.sum(), 1.0)  # fraction of assignments
    lb_loss = num_experts * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    aux = {"lb_loss": lb_loss, "z_loss": z_loss, "expert_load": load1}
    return y, aux


# ---- structure-aware expert scheduling (paper technique, beyond-paper) ----
def expert_activity(load_ema: np.ndarray, load_now: np.ndarray,
                    alpha: float = 0.75, ema: float = 0.9) -> np.ndarray:
    """AD-analogue for experts (Eq. 1/2 re-read): 'in-degree' = tokens routed
    now, 'out-degree' = historical load; activity blends them just as
    D(v) = D_o + alpha*D_i blends the two degree directions."""
    new_ema = ema * load_ema + (1 - ema) * load_now
    return new_ema + alpha * load_now, new_ema


def rebalance_plan(activity: np.ndarray, num_shards: int) -> np.ndarray:
    """Greedy hot/cold re-binning: order experts by activity (descending) and
    deal them round-robin-by-load onto EP shards, so each shard's predicted
    load is even — the paper's hot/cold partition balancing, with experts as
    vertices. Returns perm such that expert i should live at slot perm[i]."""
    e = activity.shape[0]
    order = np.argsort(-activity)
    shard_load = np.zeros(num_shards)
    shard_fill = [[] for _ in range(num_shards)]
    per_shard = e // num_shards
    for idx in order:
        k = int(np.argmin(np.where(
            np.array([len(f) for f in shard_fill]) < per_shard,
            shard_load, np.inf)))
        shard_fill[k].append(idx)
        shard_load[k] += activity[idx]
    perm = np.empty(e, dtype=np.int64)
    slot = 0
    for f in shard_fill:
        for idx in f:
            perm[idx] = slot
            slot += 1
    return perm
