"""Mamba2 SSD (state-space duality) mixer: chunked prefill + O(1) decode.

Chunked algorithm (SSD, arXiv:2405.21060 §6) in pure JAX:
  * intra-chunk: quadratic attention-like term (Q x Q decay-masked Gram
    matrix per head) — MXU-friendly;
  * inter-chunk: per-chunk states carried by a short scan (nc steps).
The naive per-step recurrence in kernels/ref.py::ssd_scan is the oracle;
tests assert allclose across shapes/dtypes. Decode carries (state, conv
window) — no KV cache, which is what makes long_500k tractable (DESIGN.md).

Layout: x (B, S, H, P); B/C projections are shared across heads (1 group);
A is per-head scalar decay, dt per-head per-step.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def ssd_chunked(x, a_log, b, c, dt, chunk: int = 128,
                return_state: bool = False):
    """x: (B,S,H,P), a_log: (H,), b/c: (B,S,N), dt: (B,S,H) -> y (B,S,H,P).

    Exactly equal (up to fp error) to the sequential recurrence:
        state_t = exp(dt_t * A) * state_{t-1} + (x_t * dt_t) (x) b_t
        y_t     = <state_t, c_t>

    return_state=True additionally returns the final state (B,H,P,N) —
    used by prefill to seed the decode recurrence.
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, f"seq {s} not divisible by chunk {q}"
    nc = s // q
    f32 = jnp.float32
    a = -jnp.exp(a_log.astype(f32))  # (H,) negative decay rates

    xs = x.reshape(bsz, nc, q, h, p).astype(f32)
    bs = b.reshape(bsz, nc, q, n).astype(f32)
    cs = c.reshape(bsz, nc, q, n).astype(f32)
    dts = dt.reshape(bsz, nc, q, h).astype(f32)

    da = dts * a  # (B,nc,Q,H) log-decay per step
    ld = jnp.cumsum(da, axis=2)  # inclusive within-chunk cumulative log-decay
    u = xs * dts[..., None]  # effective inputs (B,nc,Q,H,P)

    # --- intra-chunk (causal quadratic term) ---
    gram = jnp.einsum("bcqn,bcsn->bcqs", cs, bs)  # (B,nc,Q,Q)
    # decay from step s (exclusive) to step q (inclusive), per head
    ldiff = ld[:, :, :, None, :] - ld[:, :, None, :, :]  # (B,nc,Q,S,H)
    causal = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(ldiff), 0.0)
    y_intra = jnp.einsum("bcqs,bcqsh,bcshp->bcqhp", gram, decay, u)

    # --- chunk states: contribution of each chunk to its final state ---
    l_last = ld[:, :, -1:, :]  # (B,nc,1,H)
    state_decay = jnp.exp(l_last - ld)  # decay from step s to chunk end
    chunk_states = jnp.einsum("bcqhp,bcqn,bcqh->bchpn", u, bs, state_decay)

    # --- inter-chunk recurrence over nc (sequential, nc is small) ---
    chunk_total = jnp.exp(l_last[:, :, 0, :])  # (B,nc,H) whole-chunk decay

    def step(carry, inp):
        s_c, d_c = inp  # (B,H,P,N), (B,H)
        new = carry * d_c[..., None, None] + s_c
        return new, carry  # emit the PREVIOUS state (pre-chunk carry)

    init = jnp.zeros((bsz, h, p, n), f32)
    final_state, prev_states = lax.scan(
        step, init, (jnp.moveaxis(chunk_states, 1, 0),
                     jnp.moveaxis(chunk_total, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (B,nc,H,P,N)

    # --- inter-chunk contribution ---
    y_inter = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", cs, prev_states,
                         jnp.exp(ld))
    y = (y_intra + y_inter).reshape(bsz, s, h, p).astype(x.dtype)
    if return_state:
        return y, final_state
    return y


def ssd_decode_step(state, x_t, a_log, b_t, c_t, dt_t):
    """One-token recurrence. state: (B,H,P,N); x_t: (B,H,P); b_t/c_t: (B,N);
    dt_t: (B,H). Returns (new_state, y_t (B,H,P))."""
    f32 = jnp.float32
    a = -jnp.exp(a_log.astype(f32))
    decay = jnp.exp(dt_t.astype(f32) * a[None])  # (B,H)
    upd = jnp.einsum("bhp,bn->bhpn", x_t.astype(f32) * dt_t[..., None]
                     .astype(f32), b_t.astype(f32))
    state = state * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, c_t.astype(f32))
    return state, y.astype(x_t.dtype)


def causal_conv(x, w, cache=None):
    """Depthwise causal conv. x: (B, S, C); w: (K, C). With a cache
    ((B, K-1, C)) performs streaming decode and returns the new cache."""
    k = w.shape[0]
    if cache is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = cache.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+K-1, C)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None]
              for i in range(k))
    new_cache = xp[:, -(k - 1):, :] if k > 1 else pad
    return out.astype(x.dtype), new_cache
