"""Gemini-style synchronous baseline (the system class the paper compares
against: full BSP sweeps, static partitions, every block loaded every
iteration).

Same vertex-program interface, same convergence test (SUM of per-block mean
SD-delta < T2), same metric accounting — so the comparison in
benchmarks/bench_runtime.py isolates exactly the paper's contribution
(structure-aware scheduling), not implementation noise.

A ``frontier`` mode is included for honesty on traversal algorithms: it only
*counts* loads for blocks actually touched by the frontier (Gemini's
sparse/dense dual mode); compute is still the full sweep (dense pull), which
is the stronger baseline on CPU/TPU vector hardware.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.core.algorithms import VertexProgram
from repro.core.engine import (EngineConfig, RunResult, edge_data,
                               make_tiled_processor)
from repro.core.graph import Graph, symmetrize
from repro.core.metrics import Metrics, Timer, block_io_bytes
from repro.core.partition import build_tiled_storage


class BaselineEngine:
    def __init__(self, graph: Graph, program: VertexProgram,
                 config: EngineConfig = EngineConfig(), frontier: bool = True):
        self.program = program
        self.config = config
        self.frontier = frontier
        g = symmetrize(graph) if program.needs_symmetric else graph
        self.graph = g
        # Identical chunking (without the AD sort) => identical block
        # accounting units. Blocks here are plain id-order chunks, which is
        # what a static chunk-partitioned system uses. The full sweep runs
        # through the same tiled block processor as the structure-aware
        # engine, so the benchmark comparison isolates scheduling, not
        # implementation differences.
        self.num_blocks = max(-(-g.n // config.block_size), 1)
        self.store = build_tiled_storage(g, config.block_size,
                                         self.num_blocks)
        vals0, aux0 = program.init(g)
        self._values_len = self.num_blocks * config.block_size
        pad = self._values_len - g.n
        self.values0 = (np.concatenate(
            [vals0, np.zeros(pad, dtype=vals0.dtype)]) if pad else vals0)
        self.aux = jnp.asarray(aux0)
        self.out_deg_np = g.out_deg
        self._ed = edge_data(self.store, self.aux)
        self._step = jax.jit(self._make_step())

    def _make_step(self):
        program, g = self.program, self.graph
        c = self.config.block_size
        nb = self.num_blocks
        process_one, _, _ = make_tiled_processor(
            program, self.store, c, g.n, g.n, self.config.use_pallas)
        rows = jnp.arange(nb, dtype=jnp.int32)

        def step(ed, values):
            # lax.map, not vmap: batched tile loops run in lockstep until
            # the LAST lane finishes, so vmap would make every block pay the
            # largest block's tile count; mapped blocks pay their own.
            _, news, psd, _ = jax.lax.map(
                lambda r: process_one(ed, values, r), rows)
            new = news.reshape(nb * c)
            delta = program.sd_delta(values, new)
            changed = (delta > 0)
            return new, psd, changed.sum()
        return step

    def run(self, max_iterations: int | None = None) -> RunResult:
        cfg = self.config
        max_it = max_iterations or cfg.max_iterations
        values = jnp.asarray(self.values0)
        metrics = Metrics()
        history = []
        # frontier accounting: which blocks would a sparse engine touch?
        frontier_mask = np.ones(self.graph.n, dtype=bool)
        block_of = np.arange(self.graph.n) // cfg.block_size
        bytes_per_block = self._bytes_per_block()

        with Timer() as t:
            it = 0
            while it < max_it:
                values, psd, nchanged = self._step(self._ed, values)
                psd_host = np.asarray(psd)
                metrics.updates += self.graph.n
                metrics.edges_processed += self.graph.m
                if self.frontier:
                    touched = np.unique(block_of[frontier_mask])
                else:
                    touched = np.arange(self.num_blocks)
                metrics.block_loads += int(touched.size)
                metrics.bytes_loaded += int(bytes_per_block[touched].sum())
                history.append({"iteration": it,
                                "psd_sum": float(psd_host.sum()),
                                "active": int(nchanged),
                                "scheduled": int(touched.size)})
                it += 1
                if float(psd_host.sum()) < cfg.t2:
                    metrics.converged = True
                    break
                # next frontier: vertices with changed in-neighbours
                if self.frontier:
                    delta_v = psd_host[block_of] > 0  # block-granular change
                    frontier_mask = delta_v
        metrics.iterations = it
        metrics.wall_time_s = t.elapsed
        return RunResult(values=np.asarray(values)[:self.graph.n],
                         metrics=metrics, history=history)

    def _bytes_per_block(self) -> np.ndarray:
        """Edges per id-order block via indptr differences; shared cost
        model (metrics.block_io_bytes) with the structure-aware engine."""
        c = self.config.block_size
        idx = np.arange(0, self.graph.n, c)
        idx = np.append(idx, self.graph.n)
        edges = np.diff(self.graph.in_indptr[idx])
        if edges.size < self.num_blocks:
            edges = np.pad(edges, (0, self.num_blocks - edges.size))
        return block_io_bytes(edges[:self.num_blocks], c)
