"""Fault-tolerant checkpointing: atomic, async, keep-N, mesh-agnostic.

Layout: <dir>/step_<k>/arrays.npz + meta.json, written to a tmp dir and
renamed (atomic on POSIX) so a crash mid-write never corrupts the latest
checkpoint. Arrays are stored logically-unsharded with their tree structure
in meta; restore lays them out against ANY mesh/sharding (elastic resize —
the reshard test saves on an 8-device mesh and restores on 4).

At real pod scale the same interface writes per-host shards (one npz per
jax.process_index()); the single-host path is what runs here.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np

SEP = "/"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}{SEP}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}{SEP}"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: dict):
    tree: dict = {}
    for key, v in flat.items():
        parts = key.split(SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


def reshard(tree, shardings):
    """Lay a host-side pytree out against (possibly different) shardings."""
    return jax.tree.map(
        lambda x, s: jax.device_put(np.asarray(x), s), tree, shardings)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- write ---------------------------------------------------------------
    def save(self, step: int, tree, extra_meta: dict | None = None):
        flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
        meta = {"step": step, "time": time.time(),
                "keys": sorted(flat.keys()), **(extra_meta or {})}
        self.wait()  # one in-flight write at a time
        if self.async_write:
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, meta), daemon=True)
            self._thread.start()
        else:
            self._write(step, flat, meta)

    def _write(self, step: int, flat: dict, meta: dict):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.list_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- read ----------------------------------------------------------------
    def list_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, step: int | None = None, shardings=None):
        """Returns (tree, meta). With ``shardings`` (a matching pytree of
        NamedSharding), arrays are device_put against them — this is the
        elastic-resize path."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        tree = _unflatten(flat)
        if shardings is not None:
            tree = reshard(tree, shardings)
        return tree, meta
