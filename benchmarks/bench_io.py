"""Paper §2.1 motivation table: I/O + cache behaviour under three schedules.

Compares: dense baseline (every block, every iteration), frontier-accounted
baseline (Gemini's sparse mode), and the structure-aware schedule — on the
same convergence-skewed graph. Bytes = partition-block loads x block bytes
(the explicit TPU analogue of cache-miss traffic, DESIGN.md §2)."""
from __future__ import annotations

from repro.core import algorithms as A
from repro.core import graph as G
from repro.core.baseline import BaselineEngine
from repro.core.engine import EngineConfig, StructureAwareEngine


def run(n: int = 20000):
    cfg = EngineConfig(t2=1e-8, width=16, block_size=512)
    g = G.core_periphery_graph(n, avg_deg=8, seed=1, chords=1)
    rows = []
    dense = BaselineEngine(g, A.pagerank(), cfg, frontier=False).run()
    frontier = BaselineEngine(g, A.pagerank(), cfg, frontier=True).run()
    sa = StructureAwareEngine(g, A.pagerank(), cfg).run()
    for name, r in [("dense", dense), ("frontier", frontier), ("sa", sa)]:
        m = r.metrics
        rows.append((
            f"io/pagerank/{name}", m.wall_time_s * 1e6,
            f"loads={m.block_loads};MB={m.bytes_loaded/1e6:.1f};"
            f"edges={m.edges_processed};"
            f"bytes_per_converged_vertex={m.bytes_loaded/g.n:.0f}"))
    return rows
