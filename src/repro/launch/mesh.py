"""Production mesh builders.

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).

Topology (TPU v5e): one pod = 16x16 = 256 chips; multi-pod = 2 pods over
DCN. Axes: "pod" (DCN, slow) > "data" (DP / ZeRO) > "model" (TP/EP/SP).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1):
    """All local devices -> ("data", "model") mesh (tests / CPU training)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))


def batch_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
