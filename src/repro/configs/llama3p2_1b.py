"""llama3.2-1b [dense]: 16L d=2048 32H kv=8 ff=8192 vocab 128256, tied
embeddings, rope theta 500k. [hf:meta-llama/Llama-3.2-1B; unverified]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama3.2-1b", family="dense",
    num_layers=16, d_model=2048, num_heads=32, num_kv_heads=8,
    d_ff=8192, vocab_size=128256, head_dim=64,
    tie_embeddings=True, rope_theta=500000.0,
)
