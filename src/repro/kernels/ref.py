"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax.numpy as jnp


def edge_block_sum(msg: jnp.ndarray, dst: jnp.ndarray,
                   block_size: int) -> jnp.ndarray:
    """Segment-sum of edge messages into block-local destination slots."""
    return jnp.zeros(block_size, msg.dtype).at[dst].add(msg)


def edge_block_min(msg: jnp.ndarray, dst: jnp.ndarray, block_size: int,
                   identity: float) -> jnp.ndarray:
    """Segment-min into block-local slots (empty slots keep identity)."""
    return jnp.full(block_size, identity, msg.dtype).at[dst].min(msg)


def edge_block_max(msg: jnp.ndarray, dst: jnp.ndarray, block_size: int,
                   identity: float) -> jnp.ndarray:
    """Segment-max into block-local slots (empty slots keep identity)."""
    return jnp.full(block_size, identity, msg.dtype).at[dst].max(msg)


def attention(q, k, v, causal: bool = True, scale: float | None = None):
    """Reference (quadratic) attention. q: (B, Hq, S, D); k/v: (B, Hkv, S, D)
    with Hq a multiple of Hkv (GQA)."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / d ** 0.5
    qg = q.reshape(b, hkv, g, s, d)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    p = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return out.reshape(b, hq, s, d).astype(q.dtype)


def ssd_scan(x, a_log, b, c, dt):
    """Mamba2 SSD reference: naive per-step recurrence.

    x: (B, S, H, P) inputs, a_log: (H,) state decay log, b/c: (B, S, N)
    input/output projections (shared across heads), dt: (B, S, H) step.
    state: (B, H, P, N); y[t] = C[t] . state[t]."""
    import jax
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    a = -jnp.exp(a_log.astype(jnp.float32))  # (H,)

    def step(state, inputs):
        xt, bt, ct, dtt = inputs  # (B,H,P), (B,N), (B,N), (B,H)
        decay = jnp.exp(dtt * a[None, :])  # (B,H)
        upd = jnp.einsum("bhp,bn->bhpn", xt * dtt[..., None], bt)
        state = state * decay[..., None, None] + upd
        yt = jnp.einsum("bhpn,bn->bhp", state, ct)
        return state, yt

    state0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(b, 1, 0).astype(jnp.float32),
          jnp.moveaxis(c, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32))
    _, ys = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)  # (B, S, H, P)
