"""Out-of-core block tier + epoch persistence.

Three pieces, layered under the existing engines:

  * :mod:`repro.ooc.store` — :class:`SpillStore`: per-block residency over
    the unified tiled layout. Device memory is modeled as a fixed budget
    of resident block slots (``EngineConfig.resident_blocks``); cold
    blocks' edge tile rows are evicted to a host cache / npz disk
    segments and demand-fetched back before the schedule can touch them,
    so a budget-constrained run is bitwise-identical to the fully
    resident one.
  * :mod:`repro.ooc.prefetch` — the activity-directed policy: the PSD
    priority queue predicts the next superstep's schedule (the host
    scheduler twin is property-tested decision-identical to the fused
    device select), demand sets are protected, and retired/calm blocks —
    the paper's cold partition — are the eviction candidates.
  * :mod:`repro.ooc.snapshot` — :class:`GraphCheckpoint`: epoch
    persistence on top of :class:`repro.ckpt.manager.CheckpointManager`,
    serializing the EdgeStore truth, tile rows, fixpoint values,
    PSD/calm state and the partition plan; ``StreamingEngine.save_epoch``
    / ``StreamingEngine.restore`` warm-start a restarted service from the
    last fixpoint instead of paying cold reconvergence.
"""
from repro.ooc.snapshot import GraphCheckpoint
from repro.ooc.store import SpillStore

__all__ = ["GraphCheckpoint", "SpillStore"]
