"""Observability layer: tracing must be a pure observer.

The load-bearing property: ``run(trace=True)`` (history buffer in the
fused carry, spans recording on the host) is BITWISE identical to the
untraced run — values and every algorithmic counter — on the fused and
host paths and across warm streaming batches. Plus the timeline-sum
property (per-superstep deltas sum exactly to the aggregate ``Metrics``
counters), the ``as_dict``/@property parity contract, the Chrome-trace
exporter schema, the ring-buffer bound, and the CLI renderer.
"""
import json

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import algorithms as A
from repro.core import graph as G
from repro.core.engine import (TIMELINE_FLOAT_COLS, TIMELINE_INT_COLS,
                               EngineConfig, StructureAwareEngine,
                               _hist_cap)
from repro.core.metrics import (COUNTER_FIELDS, Metrics, ServeMetrics,
                                StreamMetrics)
from repro.obs import export as obs_export
from repro.obs import trace as obs_trace
from repro.obs.__main__ import main as obs_cli
from repro.stream import StreamingEngine, synthetic_stream

CFG = EngineConfig(t2=1e-9, width=4, block_size=128)
PROGS = {"pagerank": A.pagerank, "sssp": lambda: A.sssp(0), "cc": A.cc}


def _counters(m: Metrics) -> dict:
    return {k: getattr(m, k) for k in COUNTER_FIELDS}


# -- bitwise parity: tracing is a pure observer ------------------------------
@given(seed=st.integers(0, 20), n=st.integers(200, 600),
       algo=st.sampled_from(["pagerank", "sssp", "cc"]),
       fused=st.booleans())
@settings(max_examples=6, deadline=None)
def test_traced_run_bitwise_identical_property(seed, n, algo, fused):
    g = G.powerlaw_graph(n, avg_deg=4, seed=seed, weighted=True)
    eng = StructureAwareEngine(g, PROGS[algo](), CFG)
    plain = eng.run(fused=fused)
    traced = eng.run(fused=fused, trace=True)
    assert np.array_equal(plain.values, traced.values), \
        f"{algo} values diverged under tracing (fused={fused})"
    assert plain.metrics.iterations == traced.metrics.iterations
    assert _counters(plain.metrics) == _counters(traced.metrics)
    assert plain.metrics.converged == traced.metrics.converged
    assert plain.timeline is None
    assert traced.timeline is not None
    assert len(traced.timeline) == traced.metrics.iterations


# -- the timeline-sum property -----------------------------------------------
@given(seed=st.integers(0, 20), algo=st.sampled_from(["pagerank", "sssp"]),
       fused=st.booleans(), adaptive=st.booleans())
@settings(max_examples=6, deadline=None)
def test_timeline_sums_to_aggregate_counters_property(seed, algo, fused,
                                                      adaptive):
    """Every ``block_io_bytes``-derived counter reconstructed by summing
    the per-superstep timeline equals the aggregate ``Metrics`` total —
    the rows go through the same per-block accounting table."""
    g = G.powerlaw_graph(400, avg_deg=5, seed=seed, weighted=True)
    cfg = EngineConfig(t2=1e-9, width=4, block_size=128,
                       adaptive=adaptive)
    res = StructureAwareEngine(g, PROGS[algo](), cfg).run(
        fused=fused, trace=True)
    tl = res.timeline
    assert len(tl) == res.metrics.iterations
    for field in COUNTER_FIELDS:
        assert sum(r[field] for r in tl) == getattr(res.metrics, field), \
            f"timeline {field} sum != aggregate (fused={fused})"
    cols = set(TIMELINE_INT_COLS) | set(TIMELINE_FLOAT_COLS) \
        | {"superstep", "width"}
    for r in tl:
        assert cols <= set(r)
    assert [r["superstep"] for r in tl] == list(range(len(tl)))


def test_streaming_warm_batches_identical_under_recording():
    """Two identical streaming engines, one ingesting with a recorder
    installed: per-batch reports and final values are bitwise equal, and
    the recorder holds the ingest/reconverge/run span hierarchy."""
    g = G.powerlaw_graph(300, avg_deg=4, seed=3, weighted=True)
    batches = synthetic_stream(g, 3, 30, seed=4, delete_frac=0.25,
                               weighted=True)
    plain = StreamingEngine(g, A.pagerank(), CFG)
    traced = StreamingEngine(g, A.pagerank(), CFG)
    with obs_trace.recording() as rec:
        reps_t = [traced.ingest(b) for b in batches]
    reps_p = [plain.ingest(b) for b in batches]
    for rp, rt in zip(reps_p, reps_t):
        assert rp.iterations == rt.iterations
        assert rp.edges_processed == rt.edges_processed
        assert rp.dirty_blocks == rt.dirty_blocks
        assert rp.bytes_uploaded == rt.bytes_uploaded
    assert np.array_equal(plain.values, traced.values)
    names = {e["name"] for e in rec.events if e["type"] == "span"}
    assert {"ingest", "reconverge", "run", "chunk"} <= names
    ing = [e for e in rec.events
           if e["type"] == "span" and e["name"] == "ingest"]
    assert len(ing) == len(batches)
    assert all(e["args"]["iterations"] == rp.iterations
               for e, rp in zip(ing, reps_p))


def test_run_trace_autodetects_installed_recorder():
    g = G.uniform_graph(200, deg=4, seed=0, weighted=True)
    eng = StructureAwareEngine(g, A.pagerank(), CFG)
    assert eng.run().timeline is None
    with obs_trace.recording() as rec:
        res = eng.run()  # trace=None + installed recorder -> traced
    assert res.timeline is not None
    assert any(e["type"] == "counter" for e in rec.events)
    assert eng.run().timeline is None  # uninstalled again


# -- as_dict / @property parity ----------------------------------------------
@pytest.mark.parametrize("cls", [Metrics, StreamMetrics, ServeMetrics])
def test_every_property_lands_in_as_dict(cls):
    m = cls()
    d = m.as_dict()
    props = [name for klass in type(m).__mro__
             for name, attr in vars(klass).items()
             if isinstance(attr, property)]
    assert props, f"{cls.__name__} grew property-less — update the test"
    for name in props:
        assert name in d, f"{cls.__name__}.{name} missing from as_dict()"
        assert d[name] == getattr(m, name)
    # and the dataclass fields are all still there too
    import dataclasses
    for f in dataclasses.fields(cls):
        assert f.name in d


# -- recorder / exporter ------------------------------------------------------
def test_ring_buffer_bounds_memory_and_counts_drops():
    rec = obs_trace.TraceRecorder(capacity=8)
    for i in range(20):
        with rec.span("s", cat="t", i=i):
            pass
    assert len(rec.events) == 8
    assert rec.dropped == 12
    # oldest dropped, newest kept
    assert [e["args"]["i"] for e in rec.events] == list(range(12, 20))


def test_span_without_recorder_is_noop():
    assert obs_trace.current() is None
    with obs_trace.span("x", cat="y", a=1) as h:
        h.set(b=2)  # must not raise
    obs_trace.instant("z")  # must not raise
    assert obs_trace.current() is None


def test_nested_spans_depth_and_args():
    with obs_trace.recording() as rec:
        with obs_trace.span("outer", cat="t") as o:
            with obs_trace.span("inner", cat="t"):
                pass
            o.set(k=3)
    spans = {e["name"]: e for e in rec.events}
    assert spans["inner"]["depth"] == 1
    assert spans["outer"]["depth"] == 0
    assert spans["outer"]["args"] == {"k": 3}
    assert spans["outer"]["dur"] >= spans["inner"]["dur"]


def test_chrome_export_schema_valid(tmp_path):
    with obs_trace.recording() as rec:
        with obs_trace.span("a", cat="x", n=1):
            rec.counter_rows("c", [{"v": 1, "skip": "str"},
                                   {"v": 2}], 0.0, 1.0)
        rec.instant("mark", note="hi")
    payload = obs_export.to_chrome(rec, meta={"suite": "unit"})
    assert obs_export.validate(payload) == []
    phs = [e["ph"] for e in payload["traceEvents"]]
    assert phs.count("C") == 2 and "X" in phs and "i" in phs
    cs = [e for e in payload["traceEvents"] if e["ph"] == "C"]
    assert all("skip" not in e["args"] for e in cs)  # non-numeric filtered
    assert cs[0]["ts"] < cs[1]["ts"]  # interpolated placement
    assert payload["otherData"]["suite"] == "unit"
    p = obs_export.write(rec, str(tmp_path / "t.json"))
    assert obs_export.validate(json.load(open(p))) == []


def test_validate_rejects_malformed_payloads():
    assert obs_export.validate([]) != []
    assert obs_export.validate({}) != []
    bad = {"traceEvents": [
        {"ph": "Z", "name": "x", "pid": 1, "tid": 1, "ts": 0},
        {"ph": "X", "name": "x", "pid": 1, "tid": 1, "ts": -1},
        {"ph": "C", "name": "c", "pid": 1, "tid": 1, "ts": 0,
         "args": {"v": "nan"}},
    ]}
    errs = obs_export.validate(bad)
    assert len(errs) >= 3


def test_cli_render_and_validate(tmp_path, capsys):
    g = G.uniform_graph(200, deg=4, seed=1, weighted=True)
    with obs_trace.recording() as rec:
        StructureAwareEngine(g, A.pagerank(), CFG).run()
    path = obs_export.write(rec, str(tmp_path / "trace_run.json"))
    assert obs_cli(["validate", path]) == 0
    assert obs_cli(["render", path, "--limit", "10"]) == 0
    out = capsys.readouterr().out
    assert "valid chrome-trace JSON" in out
    assert "phase breakdown" in out and "engine/run" in out
    assert "superstep counters" in out


# -- history-capacity buckets -------------------------------------------------
def test_hist_cap_pow2_buckets():
    assert _hist_cap(1) == 16 and _hist_cap(16) == 16
    assert _hist_cap(17) == 32 and _hist_cap(32) == 32
    assert _hist_cap(33) == 64
    assert _hist_cap(1000) == 1024  # no upper clamp
    for s in range(1, 200):
        assert _hist_cap(s) >= s  # a chunk always fits its buffer
