"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

THE FIRST TWO LINES must run before any jax import: they give the CPU host
512 placeholder devices so jax.make_mesh can build the production meshes.
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

# ruff: noqa: E402
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.launch import sharding as shard_lib
from repro.launch.mesh import make_production_mesh
from repro.models import model as model_lib
from repro.models.config import SHAPES
from repro.optim import AdamWConfig
from repro.train.step import make_train_step

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4,
                "u64": 8, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def parse_collectives(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in the (post-SPMD,
    per-device) HLO. Returns {op: {count, bytes}}."""
    out = {op: {"count": 0, "bytes": 0} for op in COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for op in COLLECTIVES:
            if f" {op}(" not in stripped and f"{op}-start(" not in stripped:
                continue
            # result shapes live between '=' and the op name
            head = stripped.split(f" {op}", 1)[0]
            if "=" not in head:
                continue
            result = head.split("=", 1)[1]
            nbytes = 0
            for dt, dims in _SHAPE_RE.findall(result):
                if dt not in _DTYPE_BYTES:
                    continue
                size = 1
                for d in dims.split(","):
                    if d:
                        size *= int(d)
                nbytes += size * _DTYPE_BYTES[dt]
            out[op]["count"] += 1
            out[op]["bytes"] += nbytes
            break
    return out


def _linear_costs(meas: dict) -> dict:
    """Scan-aware cost reconstruction.

    XLA's cost_analysis counts a while body ONCE regardless of trip count
    (verified experimentally), so the full-depth compile under-reports
    anything inside the layer scan. We compile L=0 and L=1 variants — both
    count the per-layer body exactly once (L=1 scans are inlined; L=0 runs
    nothing) — giving:

        body  = report(L=1) - report(L=0)
        total = report(L=0) + L * body

    FLOPs / bytes / collective-bytes totals are microbatch-invariant (a
    micro split only re-chunks the same token work; the gradient all-reduce
    and optimizer run once either way), so the L-variants use micro=1.
    """
    out = {}
    a0, a1 = meas["A0"], meas["A1"]
    l_full = meas["L"]
    for key in ("flops", "bytes_accessed", "collective_bytes"):
        body = a1[key] - a0[key]
        out[key] = a0[key] + l_full * body
        out[f"{key}_body"] = body
        out[f"{key}_outer"] = a0[key]
    coll = {}
    for op in COLLECTIVES:
        b0 = a0["collectives"][op]["bytes"]
        b1 = a1["collectives"][op]["bytes"]
        body = b1 - b0
        coll[op] = {"bytes": b0 + l_full * body,
                    "count_once": a1["collectives"][op]["count"]}
    out["collectives_total"] = coll
    return out


def _tok_micro(cfg, shape, mesh) -> int:
    """Gradient-accumulation heuristic: ~8k tokens per device per microbatch."""
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            dp *= mesh.shape[a]
    per_dev_tokens = shape.global_batch * shape.seq_len // dp
    micro = max(per_dev_tokens // 8192, 1)
    while shape.global_batch % (micro * dp) and micro > 1:
        micro -= 1
    return micro


def _lower_variant(cfg, shape, mesh, micro: int):
    """Lower one program variant. Returns the jax Lowered object."""
    from repro.launch.mesh import batch_axes
    if cfg.shard_attn:
        model_lib.set_attention_sharding(batch_axes(mesh), "model")
    else:
        model_lib.set_attention_sharding((), None)
    params_shape = jax.eval_shape(
        lambda: model_lib.init_params(cfg, jax.random.PRNGKey(0)))
    pspecs = shard_lib.param_specs(params_shape, mesh,
                                   embed_d_shard=cfg.embed_d_shard)
    batch = configs.input_specs(cfg, shape)
    bspecs = shard_lib.batch_specs(cfg, shape, mesh)

    if shape.kind == "train":
        step = make_train_step(cfg, AdamWConfig(), num_microbatches=micro)
        state_shape = {
            "params": params_shape,
            "opt": {"m": params_shape, "v": params_shape,
                    "step": jax.ShapeDtypeStruct((), jnp.int32)}}
        sspecs = shard_lib.state_specs(state_shape, mesh,
                                       embed_d_shard=cfg.embed_d_shard)
        metrics_shape = jax.eval_shape(step, state_shape, batch)[1]
        mspecs = jax.tree.map(
            lambda _: NamedSharding(mesh, P()), metrics_shape)
        with mesh:
            return jax.jit(step, in_shardings=(sspecs, bspecs),
                           out_shardings=(sspecs, mspecs),
                           donate_argnums=(0,)).lower(state_shape, batch)
    cache_shape = configs.cache_specs(cfg, shape)
    cspecs = shard_lib.cache_sharding(cfg, shape, mesh, cache_shape)
    lspec = shard_lib.logits_spec(cfg, shape, mesh)
    if shape.kind == "prefill":
        def fn(params, batch, cache):
            return model_lib.prefill(params, cfg, batch, cache)
        with mesh:
            return jax.jit(fn, in_shardings=(pspecs, bspecs, cspecs),
                           out_shardings=(lspec, cspecs),
                           donate_argnums=(2,)).lower(
                params_shape, batch, cache_shape)

    def fn(params, tokens, cache):
        return model_lib.decode_step(params, cfg, tokens, cache)
    with mesh:
        return jax.jit(fn, in_shardings=(pspecs, bspecs["tokens"], cspecs),
                       out_shardings=(lspec, cspecs),
                       donate_argnums=(2,)).lower(
            params_shape, batch["tokens"], cache_shape)


def _shrink(cfg, layers: int):
    """Depth-k variant for the linear cost reconstruction."""
    import dataclasses
    kw = {"num_layers": layers}
    if cfg.is_encdec:
        kw["encoder_layers"] = layers
    return dataclasses.replace(cfg, **kw)


def _peak_bytes(mem) -> int:
    """Peak live bytes. jax 0.4.x's CompiledMemoryStats has no peak stat;
    the arg+output+temp sum is the standard conservative upper bound."""
    peak = int(getattr(mem, "peak_memory_in_bytes", 0) or 0)
    if peak <= 0:
        peak = int(mem.argument_size_in_bytes + mem.output_size_in_bytes
                   + mem.temp_size_in_bytes)
    return peak


def _cost_of(lowered) -> dict:
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # jax 0.4.x: one dict per program
        cost = cost[0] if cost else {}
    coll = parse_collectives(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            "collectives": coll,
            "collective_bytes": sum(v["bytes"] for v in coll.values()),
            "compiled": compiled}


def lower_cell(arch: str, shape_name: str, mesh, mesh_name: str,
               cfg_override: dict | None = None):
    """Build + lower + compile one cell (full depth for memory analysis,
    L=0/L=1 variants for scan-aware cost reconstruction)."""
    import dataclasses
    cfg = configs.get(arch)
    if cfg_override:
        cfg = dataclasses.replace(cfg, **cfg_override)
    shape = SHAPES[shape_name]

    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return {"status": "skipped",
                "reason": "pure full-attention arch: 512k dense attention "
                          "is out of scope (DESIGN.md §Arch-applicability)"}

    micro = _tok_micro(cfg, shape, mesh) if shape.kind == "train" else 1

    t0 = time.perf_counter()
    full = _cost_of(_lower_variant(cfg, shape, mesh, micro))
    t_full = time.perf_counter() - t0
    t0 = time.perf_counter()
    a0 = _cost_of(_lower_variant(_shrink(cfg, 0), shape, mesh, 1))
    a1 = _cost_of(_lower_variant(_shrink(cfg, 1), shape, mesh, 1))
    meas = {"A0": a0, "A1": a1, "L": cfg.num_layers}
    t_variants = time.perf_counter() - t0
    lin = _linear_costs(meas)

    compiled = full["compiled"]
    mem = compiled.memory_analysis()
    result = {
        "status": "ok",
        "mesh": mesh_name,
        "devices": int(mesh.size),
        "kind": shape.kind,
        # scan-aware reconstructed totals (per device)
        "flops": lin["flops"],
        "bytes_accessed": lin["bytes_accessed"],
        "collective_bytes": lin["collective_bytes"],
        "collectives": lin["collectives_total"],
        "flops_body": lin["flops_body"],
        # raw single-pass report (diagnostic)
        "flops_hlo_once": full["flops"],
        "collectives_hlo_once": full["collectives"],
        # memory proof-of-fit (full-depth program, per device)
        "argument_bytes": int(mem.argument_size_in_bytes),
        "output_bytes": int(mem.output_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "alias_bytes": int(mem.alias_size_in_bytes),
        "peak_bytes": _peak_bytes(mem),
        "compile_s": round(t_full, 2),
        "variants_s": round(t_variants, 2),
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "num_microbatches": micro,
    }
    return result


# -- the paper's engine on the production mesh -------------------------------
def lower_graph_cell(mesh, mesh_name: str, n: int = 2_000_000,
                     block_size: int = 4096, e_cap: int = 65536,
                     width_per_dev: int = 1):
    """Dry-run the distributed structure-aware sweep (hot path) at pod scale:
    vertex state replicated, blocks round-robin on the data axis, psum/pmax
    reconciliation — storage passed as abstract args (no allocation)."""
    from jax.experimental.shard_map import shard_map

    from repro.core.algorithms import pagerank
    from repro.core.engine import _combine_local

    # the dry run must lower the SAME combine op the engine runs (the
    # shared segmented-sum helper), not a hand-rolled twin of it
    pr_prog = pagerank()

    num_blocks = n // block_size
    ndev = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            ndev *= mesh.shape[a]
    width = ndev * width_per_dev

    def device_run(values, psd, src, dstl, w, valid, gids, rows, ok):
        values_in, psd_in = values, psd

        def body(i, carry):
            values, psd = carry
            row = rows[i]
            e_src = src[row]
            msg = values[e_src] * w[row]
            msg = jnp.where(valid[row], msg, 0.0)
            agg = _combine_local(pr_prog, msg, dstl[row], block_size,
                                 use_pallas=False)
            base = gids[row] * block_size
            old = jax.lax.dynamic_slice(values, (base,), (block_size,))
            new = 0.15 / n + 0.85 * agg
            values = jax.lax.dynamic_update_slice(
                values, jnp.where(ok[i], new, old), (base,))
            delta = jnp.abs(new - old).sum() / block_size
            psd = jnp.where(ok[i], psd.at[gids[row]].set(delta), psd)
            return values, psd

        values_l, psd_l = jax.lax.fori_loop(0, width_per_dev, body,
                                            (values, psd))
        values_out = values_in + jax.lax.psum(values_l - values_in, "data")
        psd_out = jax.lax.pmax(psd_l, "data")
        return values_out, psd_out

    smapped = shard_map(
        device_run, mesh=mesh,
        in_specs=(P(), P(), P(), P(), P(), P(), P(), P("data"), P("data")),
        out_specs=(P(), P()), check_rep=False)

    sds = jax.ShapeDtypeStruct
    args = (
        sds((n,), jnp.float32), sds((num_blocks,), jnp.float32),
        sds((width, e_cap), jnp.int32), sds((width, e_cap), jnp.int32),
        sds((width, e_cap), jnp.float32), sds((width, e_cap), jnp.bool_),
        sds((width,), jnp.int32), sds((width,), jnp.int32),
        sds((width,), jnp.bool_),
    )
    repl = NamedSharding(mesh, P())
    data = NamedSharding(mesh, P("data"))
    shardings = (repl, repl, data, data, data, data, data, data, data)
    lowered = jax.jit(smapped, in_shardings=shardings,
                      out_shardings=(repl, repl)).lower(*args)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # jax 0.4.x: one dict per program
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    coll = parse_collectives(compiled.as_text())
    return {"status": "ok", "mesh": mesh_name, "devices": int(mesh.size),
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            "argument_bytes": int(mem.argument_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "collectives": coll,
            "collective_bytes": sum(v["bytes"] for v in coll.values()),
            "n_vertices": n, "num_blocks": num_blocks}


# Beyond-paper optimized preset (§Perf levers validated in the hillclimb).
# Ratio-preserving head pads only (padding must keep q:kv grouping exact);
# cast_weights_once pairs with save_dots (C3: cast alone regresses under
# full remat); embed_d_shard only for untied archs.
_COMMON = {"remat_policy": "save_dots", "cast_weights_once": True}
OPTIMIZED = {
    "deepseek_moe_16b": {**_COMMON, "embed_d_shard": True},
    "granite_moe_3b_a800m": {**_COMMON, "embed_d_shard": True,
                             "pad_experts_to": 48, "capacity_factor": 1.0},
    "qwen3_14b": {**_COMMON, "embed_d_shard": True,
                  "pad_q_heads_to": 48, "pad_kv_heads_to": 16},
    "yi_6b": {**_COMMON, "embed_d_shard": True},
    "llama3p2_1b": dict(_COMMON),          # tied embeddings: no d-shard
    "mistral_nemo_12b": {**_COMMON, "embed_d_shard": True},
    # phi3 (MHA kv=32): cast/dshard regressed collectives -> remat only
    "phi3_vision_4p2b": {"remat_policy": "save_dots"},
    "mamba2_2p7b": dict(_COMMON),          # tied
    "hymba_1p5b": dict(_COMMON),           # 25:5 heads: no exact pad
    "whisper_base": {**_COMMON, "embed_d_shard": True},
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--graph", action="store_true",
                    help="also dry-run the graph engine sweep")
    ap.add_argument("--preset", default=None, choices=[None, "optimized"],
                    help="apply the §Perf optimized per-arch levers")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = configs.ARCH_NAMES if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = {}
    if os.path.exists(args.out):  # --force reruns cells, never drops others
        with open(args.out) as f:
            results = json.load(f)

    def flush():
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1, sort_keys=True)

    for multi in meshes:
        mesh_name = "pod2x16x16" if multi else "pod16x16"
        mesh = make_production_mesh(multi_pod=multi)
        if args.graph:
            key = f"graph_pagerank/sweep/{mesh_name}"
            if key not in results or results[key].get("status") == "error":
                print(f"[dryrun] {key} ...", flush=True)
                try:
                    results[key] = lower_graph_cell(mesh, mesh_name)
                except Exception as e:  # noqa: BLE001
                    results[key] = {"status": "error", "error": repr(e),
                                    "trace": traceback.format_exc()[-2000:]}
                flush()
        for arch in archs:
            for shape_name in shapes:
                key = f"{arch}/{shape_name}/{mesh_name}"
                if key in results and results[key].get("status") != "error" \
                        and not args.force:
                    continue
                print(f"[dryrun] {key} ...", flush=True)
                t0 = time.perf_counter()
                override = (dict(OPTIMIZED.get(arch, {}))
                            if args.preset == "optimized" else None)
                if override and SHAPES[shape_name].kind == "decode":
                    # head pads double the KV cache: train/prefill only
                    override.pop("pad_q_heads_to", None)
                    override.pop("pad_kv_heads_to", None)
                try:
                    results[key] = lower_cell(arch, shape_name, mesh,
                                              mesh_name,
                                              cfg_override=override)
                except Exception as e:  # noqa: BLE001
                    results[key] = {"status": "error", "error": repr(e),
                                    "trace": traceback.format_exc()[-2000:]}
                print(f"[dryrun] {key}: {results[key]['status']} "
                      f"({time.perf_counter()-t0:.1f}s)", flush=True)
                flush()
    flush()
    bad = {k: v for k, v in results.items() if v.get("status") == "error"}
    print(f"[dryrun] done: {len(results)} cells, {len(bad)} errors")
    for k, v in bad.items():
        print(f"  ERROR {k}: {v['error']}")


if __name__ == "__main__":
    main()
