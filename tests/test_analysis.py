"""The contract checker (src/repro/analysis) — three layers:

  * each seeded-violation fixture in tests/analysis_fixtures makes the
    relevant rule fire (and the CLI exit nonzero);
  * the real tree is clean (the CLI exits 0 — this is the CI gate);
  * the golden-jaxpr file round-trips (regenerate -> identical).
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import contracts, lint

REPO = Path(__file__).resolve().parents[1]
FIXTURES = Path(__file__).parent / "analysis_fixtures"


def _run_cli(*args, extra_env=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    if extra_env:
        env.update(extra_env)
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=600)


# -- AST lint rules on the seeded fixtures -----------------------------------
def _rules(path: Path) -> set:
    return {f.rule for f in lint.lint_file(path)}


def test_fixture_host_sync_fires_ra001():
    rules = _rules(FIXTURES / "bad_host_sync.py")
    assert "RA001" in rules


def test_fixture_read_after_donate_fires_ra002():
    findings = lint.lint_file(FIXTURES / "bad_read_after_donate.py")
    ra002 = [f for f in findings if f.rule == "RA002"]
    assert ra002, findings
    # the rebind idiom (commit_ok) must NOT be flagged: exactly one site
    assert len(ra002) == 1
    assert "checksum" in ra002[0].msg or ra002[0].line


def test_fixture_loop_closure_fires_ra003():
    assert "RA003" in _rules(FIXTURES / "bad_loop_closure.py")


def test_fixture_nondet_fires_ra004():
    findings = [f for f in lint.lint_file(FIXTURES / "bad_nondet.py")
                if f.rule == "RA004"]
    # np.random.random, time.time, random.getrandbits
    assert len(findings) >= 3, findings


def test_lint_clean_on_real_tree():
    findings = lint.lint_paths([REPO / "src" / "repro"])
    assert findings == [], findings


# -- CLI: fixtures exit nonzero, clean tree exits zero -----------------------
def test_cli_nonzero_on_lint_fixture():
    r = _run_cli("--check", "--no-trace", "--paths",
                 str(FIXTURES / "bad_host_sync.py"))
    assert r.returncode == 1, r.stdout + r.stderr
    assert "RA001" in r.stdout


def test_cli_nonzero_on_contract_fixture():
    r = _run_cli(
        "--check", "--paths", str(FIXTURES / "bad_loop_closure.py"),
        "--extra-contracts", "analysis_fixtures.bad_aux_gather",
        extra_env={"PYTHONPATH": str(REPO / "tests") + os.pathsep
                   + str(REPO / "src")})
    assert r.returncode == 1, r.stdout + r.stderr
    # jaxpr denylist catches the argsort aux_fn; the concrete probe
    # catches the numpy mean-normalize; the two-graph differential
    # catches the degree-seeded init
    assert "TC001" in r.stdout
    assert "TC002" in r.stdout


def test_cli_zero_on_clean_tree():
    r = _run_cli("--check")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "0 finding(s)" in r.stdout


# -- golden jaxprs -----------------------------------------------------------
def test_golden_round_trips():
    import jax

    from repro.analysis import tracecheck
    committed = json.loads(tracecheck.GOLDEN_PATH.read_text())
    if committed["jax_version"] != jax.__version__:
        pytest.skip("golden traced under a different jax version")
    assert tracecheck.golden_entries() == committed["entries"]


def test_golden_drift_detected(tmp_path):
    from repro.analysis import tracecheck
    committed = json.loads(tracecheck.GOLDEN_PATH.read_text())
    drifted = dict(committed,
                   entries=dict(committed["entries"],
                                device_select_w4="0" * 16))
    fake = tmp_path / "golden_jaxprs.json"
    fake.write_text(json.dumps(drifted))
    findings, status = tracecheck.check_golden(fake)
    assert status == "ok"
    assert any(f.rule == "TC005" and "device_select_w4" in f.msg
               for f in findings), findings


def test_golden_missing_is_a_finding(tmp_path):
    from repro.analysis import tracecheck
    findings, status = tracecheck.check_golden(tmp_path / "nope.json")
    assert status == "missing"
    assert [f.rule for f in findings] == ["TC005"]


def test_golden_other_jax_version_skips(tmp_path):
    from repro.analysis import tracecheck
    committed = json.loads(tracecheck.GOLDEN_PATH.read_text())
    stale = dict(committed, jax_version="0.0.0")
    fake = tmp_path / "golden_jaxprs.json"
    fake.write_text(json.dumps(stale))
    findings, status = tracecheck.check_golden(fake)
    assert status == "skipped" and findings == []


# -- registry ----------------------------------------------------------------
def test_discovery_finds_all_contract_kinds():
    reg = contracts.discover()
    kinds = {c.kind for c in reg}
    assert kinds == {"elementwise", "structure_independent",
                     "decision_identical", "one_executable_per",
                     "deterministic"}
    # every program factory's closures re-register under one key each:
    # repeat discovery must not grow the registry
    n = len(reg)
    assert len(contracts.discover()) == n


def test_trace_checks_clean_on_registered_contracts():
    from repro.analysis import tracecheck
    findings = tracecheck.check_contracts(contracts.discover())
    assert findings == [], findings


# -- bytecode guard ----------------------------------------------------------
def test_bytecode_guard_flags_staged_pyc(tmp_path):
    from repro.analysis.__main__ import bytecode_guard
    # the real checkout must be clean
    assert bytecode_guard() == []
    repo = tmp_path / "r"
    repo.mkdir()
    subprocess.run(["git", "init", "-q"], cwd=repo, check=True)
    bad = repo / "__pycache__"
    bad.mkdir()
    (bad / "m.cpython-311.pyc").write_bytes(b"\x00")
    subprocess.run(["git", "add", "-f", "__pycache__"], cwd=repo,
                   check=True)
    out = subprocess.run(
        ["git", "ls-files", "--cached"], cwd=repo,
        capture_output=True, text=True, check=True).stdout
    assert "__pycache__/m.cpython-311.pyc" in out
