"""Unit + property tests: degrees (Eq. 1/2), partitioning (Alg. 1)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import degrees, graph as G
from repro.core.partition import build_plan


def test_degree_function_eq1():
    # 0 -> 1, 0 -> 2, 1 -> 2 : out = [2,1,0], in = [0,1,2]
    g = G.from_edges(3, [0, 0, 1], [1, 2, 2])
    d = degrees.degree_function(g, alpha=0.5)
    assert np.allclose(d, [2 + 0.0, 1 + 0.5, 0 + 1.0])


def test_degree_function_alpha_bounds():
    g = G.from_edges(2, [0], [1])
    with pytest.raises(ValueError):
        degrees.degree_function(g, alpha=0.0)


def test_active_degree_eq2_hand():
    # two vertices, one edge 0 -> 1, alpha = 1: D = [1, 1], Dmax = 1
    # AD(v) = D(v) + sum_nbr D / (sqrt(Dmax) * D(v)) = 1 + 1/1 = 2
    g = G.from_edges(2, [0], [1])
    ad = degrees.active_degree(g, alpha=1.0)
    assert np.allclose(ad, [2.0, 2.0])


def test_dead_vertices_have_zero_ad():
    g = G.from_edges(4, [0, 1], [1, 0])  # 2 and 3 are isolated
    ad = degrees.active_degree(g)
    assert ad[2] == 0.0 and ad[3] == 0.0 and ad[0] > 0


def test_suggest_alpha_regimes():
    road = G.uniform_graph(2000, deg=4, seed=0)
    social = G.powerlaw_graph(2000, avg_deg=8, seed=0)
    a_road = degrees.suggest_alpha(road)
    a_social = degrees.suggest_alpha(social)
    assert 0.5 < a_road < a_social < 1.0  # paper: road->0.5, weibo->1


@given(n=st.integers(50, 400), avg=st.integers(2, 8),
       seed=st.integers(0, 10))
@settings(max_examples=15, deadline=None)
def test_partition_plan_invariants(n, avg, seed):
    g = G.powerlaw_graph(n, avg_deg=avg, seed=seed)
    plan = build_plan(g, block_size=64)
    # every vertex appears exactly once in the permutation
    assert np.array_equal(np.sort(plan.order), np.arange(n))
    # AD is non-increasing over the live prefix
    live_ad = plan.ad[:plan.n_live]
    assert np.all(np.diff(live_ad) <= 1e-9)
    # dead tail has zero AD
    assert np.all(plan.ad[plan.n_live:] == 0)
    # hot storage rows are the blocks before the barrier
    assert np.array_equal(plan.hot.block_ids,
                          np.arange(plan.barrier_block))
    # padded edge storage is lane-aligned and mask-consistent
    for store in (plan.hot, plan.cold):
        if store.num_blocks:
            assert store.capacity % 128 == 0
            assert np.array_equal(store.valid.sum(1), store.edges)
    # block edge slices cover ALL in-edges of live vertices exactly once
    total = int(plan.hot.edges.sum() + plan.cold.edges.sum())
    assert total == plan.graph.m


@given(n=st.integers(50, 400), avg=st.integers(2, 8),
       seed=st.integers(0, 10))
@settings(max_examples=15, deadline=None)
def test_unified_tiled_storage_invariants(n, avg, seed):
    g = G.powerlaw_graph(n, avg_deg=avg, seed=seed)
    plan = build_plan(g, block_size=64)
    u = plan.unified
    # lane-aligned tiles, per-block ownership covers every in-edge once
    assert u.tile % 128 == 0
    assert u.num_blocks == plan.num_blocks
    assert int(u.edges.sum()) == plan.graph.m
    for b in range(plan.num_blocks):
        t0, tc = int(u.tile_start[b]), int(u.tile_cnt[b])
        assert tc == -(-int(u.edges[b]) // u.tile)
        assert int(u.valid[t0:t0 + tc].sum()) == int(u.edges[b])
    # group storages and unified storage agree on per-block edge counts
    grouped = np.concatenate([plan.hot.edges, plan.cold.edges])
    assert np.array_equal(grouped, u.edges)


def test_block_bytes_positive(core_periphery_small):
    plan = build_plan(core_periphery_small, block_size=256)
    for b in range(plan.num_blocks):
        assert plan.block_bytes(b) > 0


def test_tiled_storage_slack_capacity():
    g = G.powerlaw_graph(300, avg_deg=4, seed=1)
    plan = build_plan(g, block_size=64)
    from repro.core.partition import build_tiled_storage
    base = build_tiled_storage(plan.graph, 64, plan.num_blocks)
    slacked = build_tiled_storage(plan.graph, 64, plan.num_blocks,
                                  slack=0.5, spare_tiles=1)
    assert np.all(slacked.tile_cnt >= base.tile_cnt + 1)  # spare tile
    assert np.array_equal(slacked.edges, base.edges)  # same live content
    # per-block live multisets identical despite the padding
    for b in range(plan.num_blocks):
        for st_ in (base, slacked):
            t0 = int(st_.tile_start[b]) * st_.tile
            e = int(st_.edges[b])
            assert int(st_.valid.reshape(-1)[t0:t0 + e].sum()) == e


def test_keep_dead_blocks():
    g = G.from_edges(10, [0, 1], [1, 0])  # vertices 2..9 isolated
    plan = build_plan(g, block_size=4, keep_dead=True)
    assert plan.n_dead == 0 and plan.n_live == 10
    assert plan.num_blocks * plan.block_size >= 10  # all vertices in blocks


# -- load_coo (satellite: exact int ids, .gz, negative-id errors) ------------
def test_load_coo_roundtrip(tmp_path):
    g = G.powerlaw_graph(120, avg_deg=4, seed=3, weighted=True)
    s, d, w = G.edges_of(g)
    path = tmp_path / "edges.txt"
    with open(path, "w") as f:
        f.write("# comment line\n% another comment\n")
        for a, b, ww in zip(s, d, w):
            f.write(f"{a} {b} {ww:.6f}\n")
    g2 = G.load_coo(str(path), n=g.n)
    assert g2.n == g.n and g2.m == g.m
    assert np.array_equal(g2.in_indptr, g.in_indptr)
    assert np.array_equal(g2.in_src, g.in_src)
    assert np.allclose(g2.in_w, g.in_w, atol=1e-5)


def test_load_coo_gzip(tmp_path):
    import gzip
    path = tmp_path / "edges.txt.gz"
    with gzip.open(path, "wt") as f:
        f.write("# tiny\n0 1\n1 2\n2 0\n")
    g = G.load_coo(str(path))
    assert g.n == 3 and g.m == 3


def test_parse_coo_exact_large_ids(tmp_path):
    """Ids above 2**53 are NOT representable in float64 — the parse must
    keep them exact (the old float path silently mapped 2**53+1 -> 2**53)."""
    big = 2**53 + 1
    path = tmp_path / "big.txt"
    path.write_text(f"0 {big}\n{big} 1\n")
    s, d, w = G.parse_coo(str(path))
    assert int(d[0]) == big and int(s[1]) == big
    assert w is None
    assert float(np.float64(big)) != big  # the corruption being guarded


def test_load_coo_inline_comments(tmp_path):
    """Trailing inline comments are stripped like np.loadtxt does — they
    must not confuse the column probe."""
    path = tmp_path / "inline.txt"
    path.write_text("0 1 # first\n1 2\n2 0 % last\n")
    g = G.load_coo(str(path))
    assert g.n == 3 and g.m == 3


def test_load_coo_ragged_columns_error(tmp_path):
    """A mixed 2/3-column file must fail loudly, not silently drop the
    weight column."""
    path = tmp_path / "ragged.txt"
    path.write_text("0 1\n1 2 0.5\n")
    with pytest.raises(ValueError, match="inconsistent column count"):
        G.load_coo(str(path))


def test_load_coo_negative_id_error(tmp_path):
    path = tmp_path / "neg.txt"
    path.write_text("0 1\n-3 2\n")
    with pytest.raises(ValueError, match="negative"):
        G.load_coo(str(path))


def test_load_coo_empty_error(tmp_path):
    path = tmp_path / "empty.txt"
    path.write_text("# nothing here\n")
    with pytest.raises(ValueError, match="no edges"):
        G.load_coo(str(path))


# -- permute (satellite: results must map back through inv) ------------------
def test_permute_roundtrip_structure():
    g = G.powerlaw_graph(200, avg_deg=4, seed=5, weighted=True)
    order = np.random.default_rng(0).permutation(g.n)
    pg, inv = G.permute(g, order)
    assert np.array_equal(inv[order], np.arange(g.n))
    # degrees travel with the relabelling
    assert np.array_equal(pg.out_deg[inv], g.out_deg)
    assert np.array_equal(pg.in_deg[inv], g.in_deg)
    # edge multiset is preserved under the relabelling
    s, d, w = G.edges_of(g)
    ps, pd, pw = G.edges_of(pg)
    a = sorted(zip(s, d, np.round(w, 5)))
    b = sorted(zip(order[ps], order[pd], np.round(pw, 5)))
    assert a == b


def test_permute_engine_results_map_back():
    """Running on a permuted graph and mapping back through inv must match
    the unpermuted run (the engine itself relies on this contract for its
    internal AD sort)."""
    from repro.core import algorithms as A
    from repro.core.engine import EngineConfig, StructureAwareEngine
    g = G.powerlaw_graph(400, avg_deg=4, seed=6, weighted=True)
    order = np.random.default_rng(1).permutation(g.n)
    pg, inv = G.permute(g, order)
    cfg = EngineConfig(t2=1e-9, width=4, block_size=128)
    plain = StructureAwareEngine(g, A.pagerank(), cfg).run()
    perm = StructureAwareEngine(pg, A.pagerank(), cfg).run()
    assert np.allclose(perm.values[inv], plain.values, rtol=1e-4, atol=1e-6)
