"""RA004 fixture: clock/unseeded randomness in schedule-affecting code.

The module opts in by carrying a @deterministic contract (exactly how
ooc/prefetch.py is marked)."""
import random
import time

import numpy as np

from repro.analysis.contracts import deterministic


@deterministic
def rank_victims(psd):
    jitter = np.random.random(psd.shape)  # unseeded: run-dependent order
    return np.argsort(psd + jitter * 1e-9)


def pick_epoch():
    return int(time.time()) ^ random.getrandbits(16)
