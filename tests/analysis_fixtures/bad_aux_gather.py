"""TC001/TC002 fixture: contract-decorated functions that violate their
contracts — an aux_fn that gathers across vertices (rank-normalized
degree: vertex i's aux depends on every other vertex) and an init whose
values depend on the edge set. Importing this module registers both with
the contract registry (--extra-contracts hook)."""
import jax.numpy as jnp
import numpy as np

from repro.analysis.contracts import elementwise, structure_independent


@elementwise
def aux_fn(out_deg, in_deg):
    # argsort couples every vertex: out[i] depends on the whole array
    order = jnp.argsort(out_deg)
    rank = jnp.zeros_like(out_deg).at[order].set(
        jnp.arange(out_deg.shape[0], dtype=out_deg.dtype))
    return rank + in_deg * 0


@elementwise
def aux_fn_host(out_deg, in_deg):
    # numpy host fn (probe path): normalizing by the mean couples vertices
    del in_deg
    return np.asarray(out_deg) / max(float(np.mean(out_deg)), 1e-9)


@structure_independent
def init(g):
    # init VALUES seeded from degrees: changes whenever the edge set does
    vals = 1.0 / np.maximum(g.out_deg, 1).astype(np.float32)
    aux = np.maximum(g.out_deg, 1).astype(np.float32)
    return vals, aux
