"""The paper's engine under shard_map over all local devices.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/distributed_graph.py
"""
import numpy as np

from repro.core import algorithms as A
from repro.core import graph as G
from repro.core.distributed import DistributedEngine
from repro.core.engine import EngineConfig, StructureAwareEngine


def main():
    g = G.core_periphery_graph(10000, avg_deg=8, seed=1, chords=1)
    prog = A.pagerank()
    cfg = EngineConfig(t2=1e-9, width=8, block_size=512)
    local = StructureAwareEngine(g, prog, cfg).run()
    dist = DistributedEngine(g, prog, cfg).run()
    ok = np.allclose(local.values, dist.values, rtol=1e-5, atol=1e-9)
    print(f"devices={len(__import__('jax').devices())} "
          f"local iters={local.metrics.iterations} "
          f"dist iters={dist.metrics.iterations} agree={ok}")


if __name__ == "__main__":
    main()
