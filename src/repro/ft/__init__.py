from repro.ft.straggler import StragglerMonitor

__all__ = ["StragglerMonitor"]
