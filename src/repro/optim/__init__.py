"""Optimizer substrate: AdamW + cosine schedule, global-norm clipping,
optional int8 error-feedback gradient compression for the slow (DCN/pod)
axis. Functional, pytree-generic, no external deps."""
from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               cosine_lr)
from repro.optim.compression import (ef_compress_psum, int8_decode,
                                     int8_encode)

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_lr",
           "int8_encode", "int8_decode", "ef_compress_psum"]
