"""StreamingEngine: ingest edge deltas, re-heat dirty blocks, reconverge.

Wraps one :class:`StructureAwareEngine` epoch and alternates

    ingest (incremental storage mutation, `apply.py`)
      -> dirty-block re-heat (affected blocks labelled hot with PSD =
         UNSEEN, convergence flags of clean blocks left converged,
         values warm-started from the previous fixpoint)
      -> fused convergence chunk (`engine._get_chunk`, the on-device
         while-loop — the steady-state path)

which is exactly the universal repartitioner's cold->hot path (§3.3)
driven by graph mutation instead of in-run decay. Because the engine's
edge state is a traced argument (`EdgeData`), the mutated tiles re-enter
the ALREADY-COMPILED superstep — no per-batch recompilation; a full plan
rebuild (and recompile) happens only when a block's slack tile run
overflows.

Non-monotone deletions: min/max programs can never take back a value, so
before the warm re-start the program's ``reset_on_delete`` hook
re-initialises every vertex whose value might (transitively) depend on a
deleted edge (KickStarter-style trimming; see `algorithms.py`). PageRank
needs no resets — its apply() ignores the old value, the warm state is
just a good initial guess.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import state as state_lib
from repro.core.algorithms import VertexProgram
from repro.core.engine import (EngineConfig, RunResult, StructureAwareEngine,
                               WarmStart, coupling_from_counts)
from repro.core.graph import Graph, edges_of, from_edges, symmetrize
from repro.core.metrics import StreamMetrics, Timer
from repro.stream.apply import EdgeStore, MutableTiledState
from repro.stream.delta import DeltaBatch


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    tile_slack: float = 0.5  # spare tile capacity fraction per block
    spare_tiles: int = 1  # flat extra tiles per block (covers empty blocks)
    warm: bool = True  # False: cold full recompute per batch (reference)


@dataclasses.dataclass
class StreamBatchReport:
    inserts: int
    deletes: int  # killed base edge copies (incl. parallel edges)
    dirty_blocks: int
    num_blocks: int
    appended_blocks: int
    rebuilt_blocks: int
    plan_rebuild: bool
    vertices_reset: int
    iterations: int
    edges_processed: int
    ingest_time_s: float
    reconverge_time_s: float
    converged: bool

    @property
    def dirty_frac(self) -> float:
        return self.dirty_blocks / max(self.num_blocks, 1)

    @property
    def latency_s(self) -> float:
        return self.ingest_time_s + self.reconverge_time_s


class StreamingEngine:
    """Long-lived engine over a mutating graph (fixed vertex set)."""

    def __init__(self, graph: Graph, program: VertexProgram,
                 config: EngineConfig = EngineConfig(),
                 stream: StreamConfig = StreamConfig()):
        self.program = program
        self.stream = stream
        self.config = dataclasses.replace(
            config, tile_slack=stream.tile_slack,
            spare_tiles=stream.spare_tiles, keep_dead_blocks=True)
        self.metrics = StreamMetrics()
        self.n = graph.n
        s, d, w = edges_of(graph)
        self._build_epoch(s, d, w)
        # bootstrap: one cold run to the initial fixpoint
        self.initial_result: RunResult = self.engine.run()
        self._values = self.initial_result.values

    # -- epoch management ----------------------------------------------------
    def _build_epoch(self, src: np.ndarray, dst: np.ndarray,
                     w: np.ndarray) -> None:
        """(Re)build engine + mutable mirrors from a base COO snapshot."""
        g = from_edges(self.n, src, dst, w)
        self.engine = StructureAwareEngine(g, self.program, self.config)
        plan = self.engine.plan
        inv = plan.inv
        sym = self.program.needs_symmetric
        self.store = EdgeStore(inv[src], inv[dst],
                               np.asarray(w, dtype=np.float32), self.n,
                               plan.num_blocks, plan.block_size, sym)
        self.tiles = MutableTiledState(plan.unified)
        # incrementally-maintained degrees of the INTERNAL (symmetrized)
        # graph, permuted order — the activity inputs (paper Eq. 1)
        self.out_deg = plan.graph.out_deg.astype(np.int64)
        self.in_deg = plan.graph.in_deg.astype(np.int64)
        # block -> block internal edge counts (staleness coupling truth)
        self.W = self.engine.coupling_counts.copy()
        self._aux = np.asarray(self.engine.aux)

    def _rebuild_epoch(self) -> None:
        ps, pd, w = self.store.live_base()
        order = self.engine.plan.order
        self._build_epoch(order[ps], order[pd], w)
        self.metrics.plan_rebuilds += 1

    # -- public state --------------------------------------------------------
    @property
    def values(self) -> np.ndarray:
        """Current converged values, indexed by original vertex id."""
        return self._values

    def current_graph(self) -> Graph:
        """The mutated base graph (original ids) — what a cold run sees."""
        ps, pd, w = self.store.live_base()
        order = self.engine.plan.order
        return from_edges(self.n, order[ps], order[pd], w)

    def activity(self, alpha: float | None = None) -> np.ndarray:
        """Incrementally-maintained per-vertex activity a*in + b*out (the
        degree function D(v) = out + alpha*in of paper Eq. 1), original
        ids — no rescan of the edge set."""
        a = self.engine.plan.alpha if alpha is None else alpha
        d = (self.out_deg + a * self.in_deg)
        return d[self.engine.plan.inv]

    # -- ingest --------------------------------------------------------------
    def ingest(self, batch: DeltaBatch) -> StreamBatchReport:
        prog, eng = self.program, self.engine
        plan = eng.plan
        c = plan.block_size
        inv = plan.inv
        self._validate(batch)
        sym = prog.needs_symmetric
        appended = rebuilt = 0
        n_reset = 0
        reset_blocks = np.empty(0, dtype=np.int64)

        with Timer() as t_ing:
            # 0. reclaim dead rows before any ids from this batch exist
            self.store.maybe_compact()
            # 1. mutate the base truth (deletes first, then inserts)
            killed = self.store.kill_pairs(inv[batch.del_src],
                                           inv[batch.del_dst])
            killed_orig = (plan.order[self.store.psrc[killed]],
                           plan.order[self.store.pdst[killed]],
                           self.store.w[killed].copy())
            ip_src, ip_dst = inv[batch.ins_src], inv[batch.ins_dst]
            ins_ids = self.store.insert(ip_src, ip_dst, batch.ins_w)
            self._bump(killed, -1)
            self._bump(ins_ids, +1)

            # 2. per-block tile mutation: blocks that lost edges (or whose
            # mirror in-edges changed) rebuild from truth; insert-only
            # blocks append into their spare slots
            rebuild_set = self._blocks_of(self.store.pdst[killed])
            if sym:
                rebuild_set = np.union1d(rebuild_set,
                                         self._blocks_of(
                                             self.store.psrc[killed]))
            ins_rows = [(ip_dst // c, ip_src, ip_dst, self.store.w[ins_ids])]
            if sym:
                ins_rows.append((ip_src // c, ip_dst, ip_src,
                                 self.store.w[ins_ids]))
            overflow = False
            for b in rebuild_set:
                if not self.tiles.rebuild(int(b),
                                          *self.store.gather_block(int(b))):
                    overflow = True
                    break
                rebuilt += 1
            append_set = np.setdiff1d(
                np.unique(np.concatenate([blk for blk, *_ in ins_rows]))
                if ins_ids.size else np.empty(0, np.int64), rebuild_set)
            if not overflow:
                for b in append_set:
                    asrc = np.concatenate(
                        [es[blk == b] for blk, es, _, _ in ins_rows])
                    adst = np.concatenate(
                        [ed[blk == b] for blk, _, ed, _ in ins_rows])
                    aw = np.concatenate(
                        [ew[blk == b] for blk, _, _, ew in ins_rows])
                    if not self.tiles.append(
                            int(b), asrc.astype(np.int32),
                            (adst - int(b) * c).astype(np.int32), aw):
                        overflow = True
                        break
                    appended += 1

            # 3. non-monotone deletions: KickStarter-style trimming before
            # the warm start (min/max programs cannot take a value back).
            # Cold reference mode restarts from program.init, so it skips
            # the trimming entirely.
            if (self.stream.warm and prog.reset_on_delete is not None
                    and killed.size):
                g_new = self._internal_graph()
                mask = np.asarray(prog.reset_on_delete(
                    g_new, self._values, *killed_orig))
                if mask.any():
                    init_vals, _ = prog.init(g_new)
                    self._values = self._values.copy()
                    self._values[mask] = init_vals[mask]
                    reset_blocks = self._blocks_of(
                        inv[np.flatnonzero(mask)])
                    n_reset = int(mask.sum())

            # 4. aux refresh from the incremental degrees; blocks whose
            # aggregates change because a SOURCE's aux changed (e.g. a
            # vertex's out-degree splits its rank differently) are dirty
            # even though their own storage did not move
            aux_dirty = np.empty(0, dtype=np.int64)
            if prog.aux_fn is not None:
                aux_new = np.asarray(
                    prog.aux_fn(self.out_deg, self.in_deg), dtype=np.float32)
                changed = np.flatnonzero(aux_new != self._aux)
                if changed.size and not overflow:
                    aux_dirty = self.store.out_blocks_of(changed)
                self._aux = aux_new

            # 5. commit to the engine — inside the ingest timer, so both
            # the worst case (overflow -> full plan rebuild) and the
            # device upload are billed to the batch's latency
            if overflow:
                # a block outgrew its slack capacity: new epoch
                # (re-permute by current activity, re-provision slack,
                # recompile); values stay warm, every block re-heats. The
                # partial appends/rebuilds made before the overflow were
                # discarded with the old tiles — do not let them count as
                # in-place maintenance
                appended = rebuilt = 0
                self._rebuild_epoch()
                plan = self.engine.plan
                dirty = np.ones(plan.num_blocks, dtype=bool)
                is_hot = np.zeros(plan.num_blocks, dtype=bool)
                is_hot[:plan.barrier_block] = True
                psd0 = state_lib.init_psd(plan.num_blocks)
            else:
                a2d = self.tiles.arrays2d()
                eng.set_edge_data(aux=self._aux, **a2d)
                eng.set_coupling(coupling_from_counts(self.W, prog, c))
                eng.edge_counts = self.tiles.fill.copy()
                dirty = np.zeros(plan.num_blocks, dtype=bool)
                for ids in (rebuild_set, append_set, aux_dirty,
                            reset_blocks):
                    dirty[ids.astype(np.int64)] = True
                is_hot = dirty.copy()
                psd0 = state_lib.warm_psd(plan.num_blocks, dirty)

        res = None
        with Timer() as t_run:
            if self.stream.warm:
                if dirty.any():
                    vals_perm = self._values[self.engine.plan.order].astype(
                        np.float32)
                    res = self.engine.run(warm=WarmStart(
                        values=self.engine.pad_values(vals_perm),
                        psd=psd0, is_hot=is_hot))
            else:
                # reference mode: cold full recompute on the SAME mutated
                # storage (program init values are structure-independent)
                res = self.engine.run()
            if res is not None:
                self._values = res.values

        report = StreamBatchReport(
            inserts=batch.n_inserts, deletes=int(killed.size),
            dirty_blocks=int(dirty.sum()),
            num_blocks=int(self.engine.plan.num_blocks),
            appended_blocks=appended, rebuilt_blocks=rebuilt,
            plan_rebuild=bool(overflow), vertices_reset=n_reset,
            iterations=res.metrics.iterations if res else 0,
            edges_processed=res.metrics.edges_processed if res else 0,
            ingest_time_s=t_ing.elapsed, reconverge_time_s=t_run.elapsed,
            converged=res.metrics.converged if res else True)
        self._absorb(report)
        return report

    # -- internals -----------------------------------------------------------
    def _validate(self, batch: DeltaBatch) -> None:
        for a in (batch.ins_src, batch.ins_dst, batch.del_src,
                  batch.del_dst):
            if a.size and (a.min() < 0 or a.max() >= self.n):
                raise ValueError(
                    f"delta vertex ids must be in [0, {self.n}) — the "
                    "streaming engine mutates edges over a fixed vertex set")

    def _blocks_of(self, vertices: np.ndarray) -> np.ndarray:
        if vertices.size == 0:
            return np.empty(0, dtype=np.int64)
        return np.unique(vertices // self.engine.plan.block_size)

    def _bump(self, ids: np.ndarray, sign: int) -> None:
        """Degree + block-coupling counts for internal copies (with mirrors
        for symmetric engines) — incremental, no edge rescans."""
        if ids.size == 0:
            return
        c = self.engine.plan.block_size
        ps, pd = self.store.psrc[ids], self.store.pdst[ids]
        np.add.at(self.out_deg, ps, sign)
        np.add.at(self.in_deg, pd, sign)
        np.add.at(self.W, (ps // c, pd // c), sign)
        if self.program.needs_symmetric:
            np.add.at(self.out_deg, pd, sign)
            np.add.at(self.in_deg, ps, sign)
            np.add.at(self.W, (pd // c, ps // c), sign)

    def _internal_graph(self) -> Graph:
        g = self.current_graph()
        return symmetrize(g) if self.program.needs_symmetric else g

    def _absorb(self, r: StreamBatchReport) -> None:
        m = self.metrics
        m.batches += 1
        m.ingest_time_s += r.ingest_time_s
        m.reconverge_time_s += r.reconverge_time_s
        m.edges_inserted += r.inserts
        m.edges_deleted += r.deletes
        m.edges_reprocessed += r.edges_processed
        m.iterations += r.iterations
        m.dirty_blocks += r.dirty_blocks
        m.blocks_seen += r.num_blocks
        m.appended_blocks += r.appended_blocks
        m.rebuilt_blocks += r.rebuilt_blocks
        m.vertices_reset += r.vertices_reset
