"""Serving driver: batched prefill + decode with a KV/state cache.

CPU-scale demo of the production serving path: batches requests, prefills
them together, then decodes greedily for N steps. The same prefill/decode
programs are what the dry-run lowers for the 16x16 / 2x16x16 meshes.

    PYTHONPATH=src python -m repro.launch.serve --arch hymba_1p5b \
        --reduced --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import model as model_lib


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3p2_1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = configs.reduced(cfg)
    max_seq = args.prompt_len + args.gen
    rng = np.random.default_rng(args.seed)

    params = model_lib.init_params(cfg, jax.random.PRNGKey(args.seed))
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len),
                     dtype=np.int32))}
    if cfg.num_patches:
        batch["patches"] = jnp.asarray(rng.normal(
            size=(args.batch, cfg.num_patches, cfg.d_model))
            .astype(np.float32), dtype=jnp.dtype(cfg.dtype))
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(rng.normal(
            size=(args.batch, args.prompt_len, cfg.d_model))
            .astype(np.float32), dtype=jnp.dtype(cfg.dtype))

    cache = model_lib.init_cache(cfg, args.batch, max_seq,
                                 enc_seq=args.prompt_len)
    prefill = jax.jit(lambda p, b, c: model_lib.prefill(p, cfg, b, c),
                      donate_argnums=(2,))
    decode = jax.jit(lambda p, t, c: model_lib.decode_step(p, cfg, t, c),
                     donate_argnums=(2,))

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch, cache)
    # prefill wrote [0, prompt_len); decoding continues from there
    cache["pos"] = jnp.asarray(
        args.prompt_len + (cfg.num_patches or 0), jnp.int32)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    t_prefill = time.perf_counter() - t0

    out_tokens = [tok]
    t0 = time.perf_counter()
    for _ in range(args.gen - 1):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        out_tokens.append(tok)
    t_decode = time.perf_counter() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"[serve] prefill {args.batch}x{args.prompt_len} in "
          f"{t_prefill*1e3:.1f}ms; {args.gen - 1} decode steps in "
          f"{t_decode*1e3:.1f}ms "
          f"({(args.gen - 1) * args.batch / max(t_decode, 1e-9):.0f} tok/s)")
    print("[serve] sample tokens:", np.asarray(gen[0, :12]))
    return np.asarray(gen)


if __name__ == "__main__":
    main()
