"""Deterministic synthetic LM data pipeline.

Sequences follow a noisy affine recurrence over the vocab so there IS
learnable structure (loss demonstrably drops in examples/train_lm.py).
Deterministic in (seed, step): restarts resume mid-stream exactly — the
property the checkpoint/restart test asserts. Sharding-friendly: batches are
built host-side then device_put against the batch sharding; at real scale
each host builds only its addressable shard (build_shard)."""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.05

    def _sequn(self, rng: np.random.Generator, n: int):
        a, c = 31, 17
        x = np.empty((n, self.seq_len + 1), np.int32)
        x[:, 0] = rng.integers(0, self.vocab_size, n)
        for t in range(self.seq_len):
            nxt = (x[:, t] * a + c) % self.vocab_size
            flip = rng.random(n) < self.noise
            nxt = np.where(flip, rng.integers(0, self.vocab_size, n), nxt)
            x[:, t + 1] = nxt
        return x

    def batch(self, step: int) -> dict:
        """Global batch for ``step`` (deterministic)."""
        rng = np.random.default_rng((self.seed, step))
        x = self._sequn(rng, self.global_batch)
        return {"tokens": x[:, :-1], "targets": x[:, 1:]}

    def build_shard(self, step: int, host_id: int, num_hosts: int) -> dict:
        """Per-host shard of the global batch (data-parallel ingestion)."""
        b = self.batch(step)
        per = self.global_batch // num_hosts
        sl = slice(host_id * per, (host_id + 1) * per)
        return {k: v[sl] for k, v in b.items()}
