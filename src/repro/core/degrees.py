"""Degree function, active degree (paper Eq. 1 / Eq. 2) and the sampled T1.

All host-side numpy: this is one-time load-time preprocessing (§3.2).
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import Graph, edges_of


def degree_function(g: Graph, alpha: float = 0.75) -> np.ndarray:
    """Eq. 1:  D(v) = D_o(v) + alpha * D_i(v),  0.5 < alpha < 1.

    alpha -> 0.5 for even (road-like) graphs, -> 1 for skewed (social) graphs.
    """
    if not (0.0 < alpha <= 1.0):
        raise ValueError(f"alpha must be in (0, 1], got {alpha}")
    return (g.out_deg + alpha * g.in_deg).astype(np.float64)


def suggest_alpha(g: Graph) -> float:
    """Pick alpha from the skew of the in-degree distribution (paper §3.1:
    road networks -> 0.5, follower graphs -> 1). We interpolate on the
    coefficient of variation of in-degree, clipped to the paper's open
    interval (0.5, 1)."""
    ind = g.in_deg.astype(np.float64)
    mean = ind.mean() if ind.size else 1.0
    cv = ind.std() / max(mean, 1e-12)
    # cv ~ 0.3 for near-regular graphs, > 3 for heavy power laws.
    t = np.clip((cv - 0.3) / 3.0, 0.0, 1.0)
    return float(0.55 + 0.40 * t)


def active_degree(g: Graph, alpha: float = 0.75) -> np.ndarray:
    """Eq. 2:  AD(v) = D(v) + sum_k D(v_k) / (sqrt(D_max) * D(v)).

    The neighbour sum runs over both in- and out-neighbours (the paper's
    'neighbor vertex structure'); zero-degree vertices get AD = 0 and are
    routed to the dead partition by the partitioner.
    """
    d = degree_function(g, alpha)
    dmax = d.max() if g.n else 1.0
    s, dsts, _ = edges_of(g)
    # sum of D over out-neighbours of v: edges v->k contribute D(k) to v.
    nbr = np.zeros(g.n, dtype=np.float64)
    np.add.at(nbr, s, d[dsts])
    # ... plus over in-neighbours of v: edges k->v contribute D(k) to v.
    np.add.at(nbr, dsts, d[s])
    dead = d <= 0
    denom = np.sqrt(max(dmax, 1e-12)) * np.where(dead, 1.0, d)
    ad = d + nbr / denom
    ad[dead] = 0.0
    return ad


def sampled_threshold(ad: np.ndarray, sample_frac: float = 0.1,
                      hot_ratio: float = 0.1, seed: int = 0) -> float:
    """HotGraph-style T1 (§3.1): sample ``sample_frac`` of the vertices and
    return the AD of the (hot_ratio * sample)-th largest sampled vertex."""
    n = ad.shape[0]
    rng = np.random.default_rng(seed)
    k = max(int(n * sample_frac), 1)
    sample = ad[rng.choice(n, size=k, replace=False)]
    idx = max(int(k * hot_ratio) - 1, 0)
    return float(np.sort(sample)[::-1][idx])
