"""Straggler mitigation bookkeeping.

On a real pod the step is a global barrier; one slow host drags everyone.
Policy implemented here (and exercised in tests with simulated timings):

  * EMA + deviation tracking of per-step wall time;
  * a step slower than ``deadline_factor`` x EMA flags a straggler event;
  * after ``evict_after`` consecutive flags the driver is told to drop to
    the rescue path — checkpoint + re-mesh without the slow host (elastic
    restart via ckpt.reshard), which is the standard large-fleet play.

The monitor is deliberately host-side and engine-agnostic: the graph engine
and the LM trainer both feed it.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class StragglerMonitor:
    deadline_factor: float = 3.0
    evict_after: int = 3
    ema_decay: float = 0.9
    ema: float | None = None
    consecutive: int = 0
    events: int = 0

    def observe(self, step_time: float) -> dict:
        """Feed one step time; returns {straggler, evict, deadline}."""
        if self.ema is None:
            self.ema = step_time
            return {"straggler": False, "evict": False,
                    "deadline": step_time * self.deadline_factor}
        deadline = self.ema * self.deadline_factor
        straggler = step_time > deadline
        if straggler:
            self.consecutive += 1
            self.events += 1
        else:
            self.consecutive = 0
            # only healthy steps update the EMA (a straggler step should not
            # inflate the baseline and mask the next one)
            self.ema = self.ema_decay * self.ema + \
                (1 - self.ema_decay) * step_time
        return {"straggler": straggler,
                "evict": self.consecutive >= self.evict_after,
                "deadline": deadline}
