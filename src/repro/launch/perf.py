"""Perf hillclimb runner (§Perf): re-lower a cell with a config variant and
record the roofline-term deltas next to the baseline.

    PYTHONPATH=src python -m repro.launch.perf \
        --cell qwen3_14b/train_4k/pod16x16 \
        --name remat_save_dots --set remat_policy=save_dots

Results append to results/perf.json as
    {cell: {baseline: {...}, variants: {name: {override, result}}}}
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

# ruff: noqa: E402
import argparse
import json

from repro.launch import dryrun
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze_cell


def parse_set(items):
    out = {}
    for it in items or []:
        k, v = it.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        out[k] = v
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True,
                    help="arch/shape/mesh, e.g. qwen3_14b/train_4k/pod16x16")
    ap.add_argument("--name", required=True)
    ap.add_argument("--set", nargs="*", default=[])
    ap.add_argument("--out", default="results/perf.json")
    ap.add_argument("--baseline-from", default="results/dryrun.json")
    args = ap.parse_args()

    arch, shape, mesh_name = args.cell.split("/")
    mesh = make_production_mesh(multi_pod=(mesh_name == "pod2x16x16"))
    override = parse_set(args.set)

    perf = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            perf = json.load(f)
    entry = perf.setdefault(args.cell, {"variants": {}})
    if "baseline" not in entry and os.path.exists(args.baseline_from):
        with open(args.baseline_from) as f:
            base = json.load(f).get(args.cell)
        if base:
            entry["baseline"] = {
                "result": {k: v for k, v in base.items()
                           if k != "collectives_hlo_once"},
                "roofline": analyze_cell(args.cell, base)}

    print(f"[perf] {args.cell} variant={args.name} override={override}")
    res = dryrun.lower_cell(arch, shape, mesh, mesh_name,
                            cfg_override=override)
    entry["variants"][args.name] = {
        "override": override,
        "result": {k: v for k, v in res.items()
                   if k != "collectives_hlo_once"},
        "roofline": analyze_cell(args.cell, res) if res.get("status") ==
        "ok" else None,
    }
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(perf, f, indent=1, sort_keys=True)

    if res.get("status") == "ok" and entry.get("baseline"):
        b = entry["baseline"]["roofline"]
        v = entry["variants"][args.name]["roofline"]
        for t in ("t_compute_s", "t_memory_s", "t_collective_s"):
            delta = (v[t] - b[t]) / b[t] * 100 if b[t] else float("nan")
            print(f"  {t}: {b[t]:.3e} -> {v[t]:.3e}  ({delta:+.1f}%)")
        print(f"  dominant: {b['dominant']} -> {v['dominant']}; "
              f"roofline frac {b['roofline_fraction']:.2%} -> "
              f"{v['roofline_fraction']:.2%}; peak GB "
              f"{b['peak_gb']:.2f} -> {v['peak_gb']:.2f}")
    else:
        print(f"  status: {res.get('status')} {res.get('error', '')}")


if __name__ == "__main__":
    main()
