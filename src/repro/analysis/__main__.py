"""CLI: ``python -m repro.analysis [--check|--update-golden]``.

``--check`` (the default) runs, in order:

  1. the bytecode guard — fail if ``__pycache__``/``.pyc`` files are
     git-tracked or staged (they are .gitignore'd; staging one is always
     an accident);
  2. the AST lint rules over ``src/repro`` (or ``--paths``);
  3. contract discovery + trace-time enforcement (skippable with
     ``--no-trace`` for the pure-AST fast path);
  4. the golden-jaxpr comparison (same-jax-version only).

Exit status is the number of findings, capped at 1 — a clean tree exits
0. ``--update-golden`` regenerates ``golden_jaxprs.json`` in place.
``--extra-contracts mod[,mod...]`` imports extra modules (e.g. a test
fixture) before discovery so their decorated functions are checked too.
"""
from __future__ import annotations

import argparse
import importlib
import subprocess
import sys
from pathlib import Path

from repro.analysis import lint

REPO_SRC = Path(__file__).resolve().parents[2]  # .../src
DEFAULT_LINT_PATH = REPO_SRC / "repro"


def bytecode_guard() -> list[lint.Finding]:
    """Fail if compiled bytecode is tracked or staged. Respects the
    repo's .gitignore by construction: ``git ls-files --cached`` lists
    exactly what git will commit."""
    try:
        out = subprocess.run(
            ["git", "ls-files", "--cached"],
            capture_output=True, text=True, timeout=30,
            cwd=REPO_SRC.parent, check=True).stdout
    except (OSError, subprocess.SubprocessError):
        return []  # not a git checkout (e.g. an installed wheel): no-op
    findings = []
    for line in out.splitlines():
        if line.endswith(".pyc") or "__pycache__" in line:
            findings.append(lint.Finding(
                "RA005", line, 0,
                "compiled bytecode is staged/tracked — `git rm --cached` "
                "it (the path is .gitignore'd)"))
    return findings


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--check", action="store_true", default=False,
                    help="run all layers (the default action)")
    ap.add_argument("--update-golden", action="store_true",
                    help="regenerate analysis/golden_jaxprs.json")
    ap.add_argument("--paths", nargs="*", default=None,
                    help="files/dirs to lint (default: src/repro)")
    ap.add_argument("--no-trace", action="store_true",
                    help="skip the trace-time layers (pure-AST mode)")
    ap.add_argument("--extra-contracts", default=None,
                    help="comma-separated modules to import before "
                         "contract discovery (fixture hooks)")
    args = ap.parse_args(argv)

    if args.update_golden:
        from repro.analysis import tracecheck
        payload = tracecheck.write_golden()
        print(f"wrote {tracecheck.GOLDEN_PATH} "
              f"({len(payload['entries'])} entries, "
              f"jax {payload['jax_version']})")
        return 0

    findings = list(bytecode_guard())
    paths = args.paths if args.paths else [DEFAULT_LINT_PATH]
    findings += lint.lint_paths(paths)

    if not args.no_trace:
        from repro.analysis import contracts, tracecheck
        discovered = contracts.discover()
        if args.extra_contracts:
            for mod in args.extra_contracts.split(","):
                importlib.import_module(mod.strip())
            discovered = contracts.registry()
        findings += tracecheck.check_contracts(discovered)
        golden_findings, status = tracecheck.check_golden()
        findings += golden_findings
        if status == "skipped":
            import jax
            print(f"golden jaxprs: SKIPPED (file traced under a "
                  f"different jax than {jax.__version__}; regenerate "
                  f"with --update-golden to re-arm)")
        n_contracts = len(discovered)
    else:
        n_contracts = 0

    for f in findings:
        print(f)
    layers = "lint" if args.no_trace else (
        f"lint+trace ({n_contracts} contracts)")
    print(f"repro.analysis: {len(findings)} finding(s) [{layers}]")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
