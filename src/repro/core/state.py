"""Partition state degree (PSD) bookkeeping + convergence test (§3.3, §4).

PSD(j) is the mean per-vertex state-degree delta from the most recent time
block j was processed (the paper accumulates SD between scheduling events;
the per-processing mean is what drives both the priority queue and the
SUM(PSD) < T2 convergence test — a forever-growing accumulator could never
cross T2, so 'accumulation' is interpreted per scheduling window; see
DESIGN.md §7).

Unprocessed blocks carry PSD = UNSEEN (a large sentinel), which (a) gives
every block first-visit priority and (b) blocks convergence until the whole
graph has been processed at least once.
"""
from __future__ import annotations

import numpy as np

UNSEEN = np.float32(1e30)


def init_psd(num_blocks: int) -> np.ndarray:
    return np.full(num_blocks, UNSEEN, dtype=np.float32)


def warm_psd(num_blocks: int, dirty: np.ndarray,
             bump: np.ndarray | None = None) -> np.ndarray:
    """PSD vector for a warm re-start over an already-converged state
    (streaming re-heat): dirty blocks carry the UNSEEN sentinel — first-visit
    priority, and convergence is blocked until every one is re-processed —
    while clean blocks start individually converged (PSD 0). Clean blocks
    re-arm through the staleness coupling when a dirty neighbour's values
    move, exactly like cold blocks re-heating mid-run.

    ``bump`` optionally seeds clean blocks with a finite PSD floor (the
    streaming engine's aux-staleness bound): the scheduler re-processes
    them by priority like any re-armed block, but — unlike UNSEEN dirty
    blocks — they carry no first-visit priority, and a bump below the
    engine's pruning floor is soundly skipped (same argument as the
    per-block T2/P prune)."""
    psd = np.zeros(num_blocks, dtype=np.float32)
    if bump is not None:
        psd = np.maximum(psd, np.asarray(bump, dtype=np.float32))
    psd[np.asarray(dirty)] = UNSEEN
    return psd


def warm_calm(num_blocks: int, armed: np.ndarray,
              retire_after: int) -> np.ndarray:
    """Block-local convergence counters for a warm restart (adaptive
    active-set execution): ``calm[b]`` counts consecutive supersteps block
    b spent under the engine's pruning floor; ``calm >= retire_after``
    marks the block retired from the active set. Armed blocks (dirty
    re-heats and aux-bumped blocks) start fresh (calm 0); clean blocks
    start already retired — they ARE individually converged, and re-enter
    the active set only when a staleness-coupling or aux bump lifts their
    PSD back over the floor (which resets calm). This is what lets a small
    delta batch start in a narrow dispatch bucket instead of paying
    full-width sweeps over converged padding."""
    calm = np.full(num_blocks, retire_after, dtype=np.int32)
    calm[np.asarray(armed, dtype=bool)] = 0
    return calm


def init_lane_psd(num_blocks: int, lane_active: np.ndarray) -> np.ndarray:
    """(P, L) per-lane PSD start state for a multi-lane query run: active
    lanes carry the UNSEEN sentinel in every block (first-visit coverage is
    per lane, served by the shared sweep), padding lanes start at 0 —
    individually converged from the first superstep, so they never hold a
    block in the active set nor block lane convergence."""
    lane_active = np.asarray(lane_active, dtype=bool)
    psd = np.zeros((num_blocks, lane_active.shape[0]), dtype=np.float32)
    psd[:, lane_active] = UNSEEN
    return psd


def fold_lane_psd(psd: np.ndarray, lane_done: np.ndarray) -> np.ndarray:
    """(P,) block scheduling priority from (P, L) per-lane PSDs: the max
    over lanes still running — the union of the lane frontiers, so a block
    hot in ANY live lane is schedulable and a retired lane stops pricing
    blocks. Numpy host version (repartition boundaries); the fused lane
    superstep applies the identical fold in jnp."""
    masked = np.where(np.asarray(lane_done, dtype=bool)[None, :], 0.0,
                      np.asarray(psd, dtype=np.float32))
    return masked.max(axis=1) if masked.shape[1] else \
        np.zeros(masked.shape[0], np.float32)


def fold_lane_psd_device(psd, lane_done):
    """Traced twin of :func:`fold_lane_psd` for the fused lane superstep."""
    import jax.numpy as jnp
    return jnp.max(jnp.where(lane_done[None, :], jnp.float32(0.0), psd),
                   axis=1)


def lane_converged_device(psd, t2: float):
    """(L,) per-lane SUM(PSD) < T2 — the paper's convergence test applied
    per lane column (same f32-sum argument as :func:`converged_device`)."""
    import jax.numpy as jnp
    return jnp.sum(psd, axis=0) < jnp.float32(t2)


def converged(psd: np.ndarray, t2: float) -> bool:
    """Paper §4: the entire graph converges when sum of PSDs < T2."""
    return bool(np.asarray(psd, dtype=np.float64).sum() < t2)


def converged_device(psd, t2: float):
    """Traced SUM(PSD) < T2 for the fused superstep. f32 sum: UNSEEN
    sentinels keep the sum far above any realistic T2 (overflow to +inf is
    also a correct 'not converged'), and near the threshold every PSD is
    tiny so the f32 accumulation error is negligible against T2."""
    import jax.numpy as jnp
    return jnp.sum(psd) < jnp.float32(t2)


def psd_threshold(psd: np.ndarray, hot_ratio: float = 0.1) -> float:
    """Adaptive T1-for-PSD used at repartition time: the hot_ratio quantile of
    the currently-seen PSDs (the paper reuses the symbol T1 for both the AD
    and the SD thresholds; we recompute it on the live distribution)."""
    seen = psd[psd < UNSEEN]
    if seen.size == 0:
        return float("inf")
    q = np.quantile(seen.astype(np.float64), 1.0 - hot_ratio)
    return float(max(q, 1e-12))
