# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20000,
                    help="graph size for the engine benchmarks")
    ap.add_argument("--only", default=None,
                    help="comma list: runtime,convergence,io,kernels")
    args = ap.parse_args()

    from benchmarks import (bench_convergence, bench_io, bench_kernels,
                            bench_runtime)
    suites = {
        "runtime": lambda: bench_runtime.run(args.n),
        "convergence": lambda: bench_convergence.run(args.n),
        "io": lambda: bench_io.run(args.n),
        "kernels": bench_kernels.run,
    }
    pick = args.only.split(",") if args.only else list(suites)
    print("name,us_per_call,derived")
    ok = True
    for key in pick:
        try:
            for name, us, derived in suites[key]():
                print(f"{name},{us:.1f},{derived}")
                sys.stdout.flush()
        except Exception as e:  # noqa: BLE001
            ok = False
            print(f"{key},-1,ERROR:{e!r}")
    if not ok:
        sys.exit(1)


if __name__ == '__main__':
    main()
