"""Seeded-violation fixtures for the repro.analysis checker tests.

Every module here intentionally violates one contract or lint rule;
tests/test_analysis.py asserts the corresponding rule FIRES on it. None
of this code is imported by the library. Ruff is configured to skip
this directory (pyproject per-file-ignores) — broken-on-purpose code
would otherwise fail the style gate it exists to test.
"""
