"""mistral-nemo-12b [dense]: 40L d=5120 32H kv=8 ff=14336, head_dim 128,
128k ctx. [hf:mistralai/Mistral-Nemo-Base-2407; hf]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mistral-nemo-12b", family="dense",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=131072, head_dim=128,
    rope_theta=1000000.0,
)
