"""Partition state degree (PSD) bookkeeping + convergence test (§3.3, §4).

PSD(j) is the mean per-vertex state-degree delta from the most recent time
block j was processed (the paper accumulates SD between scheduling events;
the per-processing mean is what drives both the priority queue and the
SUM(PSD) < T2 convergence test — a forever-growing accumulator could never
cross T2, so 'accumulation' is interpreted per scheduling window; see
DESIGN.md §7).

Unprocessed blocks carry PSD = UNSEEN (a large sentinel), which (a) gives
every block first-visit priority and (b) blocks convergence until the whole
graph has been processed at least once.

Hierarchical partitions (sub-blocks): with ``EngineConfig.subblocks = S``
every block is split into S contiguous vertex ranges and the PSD / calm
state grows a trailing sub-block axis — psd (P, S), calm (P, S), lane psd
(P, S, L). Scheduling stays block-granular: the block priority is the MAX
over its sub-blocks (:func:`fold_subblock_psd`), which preserves the Eq. 1
semantics (a block is as hot as its hottest sub-range), and convergence is
SUM over blocks of that max — identical to the paper's test at S = 1 and a
sound (conservative) over-estimate of SUM(PSD) for S > 1. Every helper in
this module is dimension-polymorphic: 1-D inputs behave exactly as before.
"""
from __future__ import annotations

import numpy as np

UNSEEN = np.float32(1e30)


def init_psd(num_blocks: int, subblocks: int | None = None) -> np.ndarray:
    """(P,) cold-start PSD vector, or (P, S) when ``subblocks`` is given
    (hierarchical engines keep per-sub-block PSDs; see module docstring)."""
    if subblocks is None:
        return np.full(num_blocks, UNSEEN, dtype=np.float32)
    return np.full((num_blocks, subblocks), UNSEEN, dtype=np.float32)


def fold_subblock_psd(psd: np.ndarray) -> np.ndarray:
    """(P,) block scheduling priority from a (P, S) per-sub-block PSD: the
    max over sub-blocks — a block is as hot as its hottest sub-range, so
    Eq. 1's priority ordering is preserved at block granularity. 1-D input
    passes through (the S = 1 engine stores (P, 1); folding a singleton
    axis is bitwise identity)."""
    psd = np.asarray(psd)
    return psd.max(axis=-1) if psd.ndim == 2 else psd


def fold_subblock_psd_device(psd):
    """Traced twin of :func:`fold_subblock_psd` for the fused superstep."""
    import jax.numpy as jnp
    return jnp.max(psd, axis=-1) if psd.ndim == 2 else psd


def warm_psd(num_blocks: int, dirty: np.ndarray,
             bump: np.ndarray | None = None) -> np.ndarray:
    """PSD vector for a warm re-start over an already-converged state
    (streaming re-heat): dirty blocks carry the UNSEEN sentinel — first-visit
    priority, and convergence is blocked until every one is re-processed —
    while clean blocks start individually converged (PSD 0). Clean blocks
    re-arm through the staleness coupling when a dirty neighbour's values
    move, exactly like cold blocks re-heating mid-run.

    ``bump`` optionally seeds clean blocks with a finite PSD floor (the
    streaming engine's aux-staleness bound): the scheduler re-processes
    them by priority like any re-armed block, but — unlike UNSEEN dirty
    blocks — they carry no first-visit priority, and a bump below the
    engine's pruning floor is soundly skipped (same argument as the
    per-block T2/P prune)."""
    psd = np.zeros(num_blocks, dtype=np.float32)
    if bump is not None:
        psd = np.maximum(psd, np.asarray(bump, dtype=np.float32))
    psd[np.asarray(dirty)] = UNSEEN
    return psd


def warm_calm(num_blocks: int, armed: np.ndarray,
              retire_after: int) -> np.ndarray:
    """Block-local convergence counters for a warm restart (adaptive
    active-set execution): ``calm[b]`` counts consecutive supersteps block
    b spent under the engine's pruning floor; ``calm >= retire_after``
    marks the block retired from the active set. Armed blocks (dirty
    re-heats and aux-bumped blocks) start fresh (calm 0); clean blocks
    start already retired — they ARE individually converged, and re-enter
    the active set only when a staleness-coupling or aux bump lifts their
    PSD back over the floor (which resets calm). This is what lets a small
    delta batch start in a narrow dispatch bucket instead of paying
    full-width sweeps over converged padding."""
    calm = np.full(num_blocks, retire_after, dtype=np.int32)
    calm[np.asarray(armed, dtype=bool)] = 0
    return calm


def warm_psd_sub(num_blocks: int, subblocks: int, dirty_sub: np.ndarray,
                 bump: np.ndarray | None = None) -> np.ndarray:
    """(P, S) warm-restart PSD: the sub-block refinement of
    :func:`warm_psd`. ``dirty_sub`` is the (P, S) bool mask of perturbed
    sub-blocks (UNSEEN re-heat); ``bump`` is the aux staleness bound —
    (P, S) when the caller resolved which sub-ranges the changed
    messages land in (the streaming aux path does), or (P,) applied to
    every sub-block of a bumped block (the conservative fallback). At
    S = 1 this is ``warm_psd`` with a trailing singleton axis, value for
    value."""
    psd = np.zeros((num_blocks, subblocks), dtype=np.float32)
    if bump is not None:
        b = np.asarray(bump, dtype=np.float32)
        psd = np.maximum(psd, b if b.ndim == 2 else b[:, None])
    psd[np.asarray(dirty_sub, dtype=bool)] = UNSEEN
    return psd


def warm_calm_sub(num_blocks: int, subblocks: int, armed_sub: np.ndarray,
                  retire_after: int) -> np.ndarray:
    """(P, S) warm-restart calm counters: armed sub-blocks start fresh,
    clean ones start individually retired (see :func:`warm_calm`) — a
    10-edit batch opens with ~10 live sub-blocks instead of ~10 live
    whole blocks."""
    calm = np.full((num_blocks, subblocks), retire_after, dtype=np.int32)
    calm[np.asarray(armed_sub, dtype=bool)] = 0
    return calm


def init_lane_psd(num_blocks: int, lane_active: np.ndarray,
                  subblocks: int | None = None) -> np.ndarray:
    """(P, L) per-lane PSD start state for a multi-lane query run — or
    (P, S, L) when ``subblocks`` is given: active lanes carry the UNSEEN
    sentinel in every (sub-)block (first-visit coverage is per lane,
    served by the shared sweep), padding lanes start at 0 — individually
    converged from the first superstep, so they never hold a block in the
    active set nor block lane convergence."""
    lane_active = np.asarray(lane_active, dtype=bool)
    shape = ((num_blocks, lane_active.shape[0]) if subblocks is None
             else (num_blocks, subblocks, lane_active.shape[0]))
    psd = np.zeros(shape, dtype=np.float32)
    psd[..., lane_active] = UNSEEN
    return psd


def fold_lane_psd(psd: np.ndarray, lane_done: np.ndarray) -> np.ndarray:
    """(P,) block scheduling priority from (P, L) per-lane PSDs — or
    (P, S, L) per-sub-block-per-lane PSDs: the max over lanes still
    running (and over sub-blocks) — the union of the lane frontiers, so a
    block hot in ANY live lane is schedulable and a retired lane stops
    pricing blocks. Numpy host version (repartition boundaries); the
    fused lane superstep applies the identical fold in jnp."""
    psd = np.asarray(psd, dtype=np.float32)
    lane_done = np.asarray(lane_done, dtype=bool)
    mask = lane_done[None, :] if psd.ndim == 2 else lane_done[None, None, :]
    masked = np.where(mask, 0.0, psd)
    if masked.shape[-1] == 0:
        return np.zeros(masked.shape[0], np.float32)
    out = masked.max(axis=-1)  # over lanes
    return out.max(axis=-1) if out.ndim == 2 else out  # over sub-blocks


def fold_lane_psd_device(psd, lane_done):
    """Traced twin of :func:`fold_lane_psd` for the fused lane superstep."""
    import jax.numpy as jnp
    mask = lane_done[None, :] if psd.ndim == 2 else lane_done[None, None, :]
    out = jnp.max(jnp.where(mask, jnp.float32(0.0), psd), axis=-1)
    return jnp.max(out, axis=-1) if out.ndim == 2 else out


def lane_sub_psd_device(psd, lane_done):
    """(P, S) lane-folded per-sub-block priority from a (P, S, L) lane
    PSD: the max over lanes still running. This is the ONE sub-block mask
    the lane sweeps apply — shared across lanes, so with a single admitted
    lane the masking decisions reduce exactly to the single-program
    engine's (serve parity); 2-D input passes through with a singleton
    sub-block axis's semantics (S = 1)."""
    import jax.numpy as jnp
    if psd.ndim == 2:
        return jnp.where(lane_done[None, :], jnp.float32(0.0), psd)
    return jnp.max(jnp.where(lane_done[None, None, :], jnp.float32(0.0),
                             psd), axis=-1)


def lane_converged_device(psd, t2: float):
    """(L,) per-lane SUM < T2 — the paper's convergence test applied per
    lane column (same f32-sum argument as :func:`converged_device`); with
    a sub-block axis the summand is each block's max over sub-blocks (the
    block priority), conservative for S > 1 and identical at S = 1."""
    import jax.numpy as jnp
    blk = jnp.max(psd, axis=1) if psd.ndim == 3 else psd
    return jnp.sum(blk, axis=0) < jnp.float32(t2)


def converged(psd: np.ndarray, t2: float) -> bool:
    """Paper §4: the entire graph converges when sum of PSDs < T2. With a
    sub-block axis the per-block summand is the max over sub-blocks."""
    folded = fold_subblock_psd(np.asarray(psd, dtype=np.float64))
    return bool(folded.sum() < t2)


def converged_device(psd, t2: float):
    """Traced SUM(PSD) < T2 for the fused superstep. f32 sum: UNSEEN
    sentinels keep the sum far above any realistic T2 (overflow to +inf is
    also a correct 'not converged'), and near the threshold every PSD is
    tiny so the f32 accumulation error is negligible against T2. With a
    sub-block axis the summand is each block's max over sub-blocks."""
    import jax.numpy as jnp
    return jnp.sum(fold_subblock_psd_device(psd)) < jnp.float32(t2)


def psd_threshold(psd: np.ndarray, hot_ratio: float = 0.1) -> float:
    """Adaptive T1-for-PSD used at repartition time: the hot_ratio quantile of
    the currently-seen PSDs (the paper reuses the symbol T1 for both the AD
    and the SD thresholds; we recompute it on the live distribution)."""
    seen = psd[psd < UNSEEN]
    if seen.size == 0:
        return float("inf")
    q = np.quantile(seen.astype(np.float64), 1.0 - hot_ratio)
    return float(max(q, 1e-12))
