"""Shared layer math: norms, RoPE, SwiGLU, initializers.

Params are plain dict pytrees of jnp arrays; per-layer tensors are stacked on
a leading (L,) axis and consumed through lax.scan (compile time and HLO size
independent of depth — required at 512 devices x 64 layers).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x, weight, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    # f32 accumulation on the d_ff contraction: under tensor parallelism this
    # reduction is sharded, and bf16 partial sums make the all-reduce diverge
    # from the single-device result by more than bf16 rounding of one matmul.
    return jnp.matmul(h, w_down,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim)


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, Dh); positions: (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, Dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def dense_init(key, shape, scale: float | None = None, dtype=jnp.float32):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)
