"""deepseek-moe-16b [moe]: 28L d=2048 16H (kv=16) fine-grained MoE:
2 shared + 64 routed experts, top-6, expert width 1408.
[arXiv:2401.06066; hf]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=102400,
    num_experts=64, experts_per_token=6, num_shared_experts=2,
    moe_d_ff=1408,
)
