"""Structure-aware iteration driver (paper §3–§4, Algorithms 1–3).

The engine executes one vertex program over a :class:`PartitionPlan`:

  * hot-labelled blocks run **sequentially** within an iteration (the paper's
    asynchronous mode — each block sees the freshest values, Maiter-style
    delta propagation through the hubs);
  * cold-labelled blocks run **batched** from a post-hot snapshot (the
    paper's synchronous mode);
  * the scheduler picks the top-PSD m hot + n cold blocks per iteration
    (Alg. 3) and the repartitioner re-labels blocks on a growing cadence
    (Alg. 2);
  * convergence is SUM_j PSD(j) < T2 (§4), with unvisited blocks carrying an
    UNSEEN sentinel so the whole graph is covered at least once.

Superstep fusion (default execution mode). One iteration =
schedule -> hot dispatch -> cold dispatch -> staleness post -> convergence
test, and the whole sequence is traced into a single jitted
``lax.while_loop`` over the unified tiled storage (``PartitionPlan.unified``
— any block id, no host-side hot/cold routing). The host is consulted only
at **repartition boundaries** (every ``repartition_interval`` iterations,
growing by ``repartition_growth``): one device->host sync per boundary pulls
the PSD vector, flushes the device-resident metric counters, snapshots
history, and re-labels blocks (Alg. 2 stays host-side — it is O(P) numpy
bookkeeping on a cadence, not per-iteration work). Host transfers per run
are therefore O(iterations / repartition_interval), not O(iterations); the
per-iteration ``np.asarray(psd)`` round-trip of the host-driven loop
dominated wall time for exactly the many-small-iteration workloads the
paper targets. The reference host-driven loop is kept as
``run(fused=False)`` (per-iteration history, and the base for the
shard_map distributed engine).

Dynamic edge state (streaming support). The tiled edge arrays, the
per-vertex aux, and the staleness-coupling matrix are **traced arguments**
of every jitted function (:class:`EdgeData`), not closure constants: the
compiled superstep is keyed only on the tile GEOMETRY (tile_start /
tile_cnt / shapes), so the streaming subsystem can mutate edges in place
and re-enter the same executable — a closure-captured array would bake the
edge list into the XLA program and force a recompile per delta batch.
``run(warm=WarmStart(...))`` re-enters convergence from an
already-converged state with only the dirty blocks re-heated (PSD =
UNSEEN, labelled hot); clean blocks start individually converged and
re-arm through the staleness coupling — the universal repartitioner's
cold->hot path (§3.3), applied to graph mutation instead of in-run decay.

Correctness beyond the paper's prose: partial scheduling needs a staleness
signal — when block j's vertices change, downstream blocks (containing j's
out-neighbours) must become schedulable again even if their own PSD already
decayed to 0 (the paper's 'cold partitions can re-heat'). We precompute the
block->affected-blocks adjacency once (host, O(m)) and bump downstream PSDs
after each iteration. Without this, min/max programs can terminate with
stale values; with it, every engine run reaches the same fixpoint as the
synchronous baseline (tested property), fused or host-driven.

Adaptive active-set execution (``EngineConfig.adaptive``, default on). The
paper's "low-activity vertices are computed less often, high-status
partitions more deeply" is made concrete with three mechanisms, applied
identically by the fused and host paths (decision parity is property
tested):

  * **block-local convergence flags** — a per-block ``calm`` counter
    (device state, updated in the staleness post) counts consecutive
    supersteps under the scheduler's pruning floor; ``calm >=
    retire_after`` retires the block from the *active set*. A
    staleness-coupling or aux bump that lifts the block's PSD back over
    the floor resets calm and re-arms it.
  * **priority-scaled inner depth** — hot slot i (PSD rank i) runs
    ``max(1, hot_inner_iters >> i)`` block-local Gauss-Seidel passes:
    deep async iteration is spent on the top of the hot queue, not on
    every scheduled block.
  * **shrinking dispatch width** — the fused chunk is compiled per
    dispatch-width bucket (powers of two down from ``cfg.width``); at
    each repartition boundary the host picks the bucket covering the live
    active set (non-retired blocks), so tail supersteps stop paying
    full-width sweeps over padded slots. Warm streaming restarts seed
    ``calm`` so only the perturbed blocks are active — a small delta
    batch starts narrow (see ``WarmStart.calm`` / ``WarmStart.i2``).

``adaptive=False`` restores the fixed-slate dispatch (constant width,
constant inner depth, floor-prune only) with the exact pre-adaptive
trajectory.

Hierarchical partitions (``EngineConfig.subblocks``, default 1). Every
block is split into S equal contiguous sub-ranges and the activity state
grows a trailing sub-block axis: psd/dmax/calm are (P, S) device arrays.
Scheduling and repartitioning stay BLOCK-granular (block priority = max
over sub-blocks, preserving Eq. 1), but inside a scheduled block the
sweep masks sub-blocks whose PSD sits under the pruning floor: their
vertices keep their values, their PSD/calm rows are left to retire, and
edge tiles covering only masked sub-ranges are skipped (tiles inherit
the CSC dst order, so a tile spans few contiguous sub-ranges). The
staleness coupling is SUB-granular at S > 1 — the count matrix grows a
destination-sub axis, (P, P, S), so an upstream delta re-arms only the
sub-ranges that actually receive edges from the moving block; without
this a single bump would arm whole rows and the P-pigeonhole would just
reappear one level down. The same t2/P floor argument that makes block
pruning safe makes sub-block pruning safe (a frozen sub-block's residual
is below the floor by construction, and any upstream movement re-arms it
through its own coupling column). ``subblocks=1`` keeps psd at (P, 1)
and the coupling at (P, P) — every fold is a bitwise identity and the
sweep bodies trace to the exact flat code path, so the PR-5 trajectory
is reproduced value for value.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.analysis.contracts import one_executable_per
from repro.core import state as state_lib
from repro.core.algorithms import LaneProgram, VertexProgram
from repro.core.graph import Graph, symmetrize
from repro.core.metrics import COUNTER_FIELDS, Metrics, Timer, \
    block_io_bytes
from repro.obs import trace as obs_trace
from repro.core.partition import (EdgeStorage, PartitionPlan, TiledStorage,
                                  build_plan)
from repro.core.repartition import RepartitionState
from repro.core.schedule import (Scheduler, Selection, make_device_select,
                                 pick_width, schedule_predictor,
                                 width_ladder)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    block_size: int = 256
    width: int = 8  # W = m + n (paper: worker count)
    i2: int = 4  # cold-admission cadence (paper I2)
    cold_frac: float = 0.25  # n/W; paper requires m > n
    repartition_interval: int = 4  # paper I1 (grows over time)
    repartition_growth: float = 1.5
    hot_inner_iters: int = 8  # async hot mode: block-local Gauss-Seidel
    hot_ratio: float = 0.1
    sample_frac: float = 0.1
    alpha: float | None = None  # Eq. 1 alpha; None -> suggest_alpha
    t2: float = 1e-6  # paper's default convergence threshold
    max_iterations: int = 100000
    stale_eps: float = 1e-12  # PSD above this marks downstream blocks dirty
    use_pallas: bool = False  # sum-combine via the Pallas spmv kernel
    fused: bool = True  # device-resident lax.while_loop superstep
    adaptive: bool = True  # active-set execution (False = fixed-slate)
    subblocks: int = 1  # sub-blocks per block (hierarchical activity tracking)
    retire_after: int = 3  # consecutive sub-floor supersteps before retire
    min_width: int = 2  # narrowest dispatch-width bucket
    # out-of-core block tier: device memory modeled as a fixed budget of
    # resident block slots. None (default) = fully resident — no spill
    # tier is built and the trajectory is bitwise-identical to before the
    # tier existed. With resident_blocks < P the engine evicts cold
    # blocks' edge tile rows to host/disk (repro.ooc.store) and pages the
    # predicted schedule back in before each superstep; budget must be
    # >= width + 2 (the scheduled slate plus the pinned pad blocks).
    resident_blocks: int | None = None
    spill_dir: str | None = None  # npz segment dir; None = host cache only
    tile_slack: float = 0.0  # spare tile capacity per block (streaming)
    spare_tiles: int = 0  # flat extra tiles per block (streaming)
    keep_dead_blocks: bool = False  # dead vertices get block slots (streaming)
    seed: int = 0


@dataclasses.dataclass
class RunResult:
    values: np.ndarray  # indexed by ORIGINAL vertex id
    metrics: Metrics
    history: list  # per-iteration dicts (for convergence curves)
    # per-SUPERSTEP trace timeline (``run(trace=True)``; None otherwise):
    # dicts with TIMELINE_INT_COLS / TIMELINE_FLOAT_COLS plus
    # superstep/width. The integer counter columns sum exactly to the
    # aggregate Metrics counters (property-tested) — the timeline is the
    # time-resolved decomposition of the same accounting, not a parallel
    # estimate.
    timeline: list | None = None


@dataclasses.dataclass(frozen=True)
class WarmStart:
    """Re-enter convergence from a previous fixpoint (streaming re-heat).

    ``values`` is in PERMUTED order, padded to the engine's value length;
    ``psd`` carries UNSEEN for dirty blocks / 0 for clean ones (see
    ``state.warm_psd``); ``is_hot`` is the dirty mask — warm runs always
    repartition in universal mode, since an arbitrary dirty set is not a
    prefix barrier.

    Adaptive extras (both ignored when ``config.adaptive`` is off):
    ``calm`` seeds the block-local convergence counters (see
    ``state.warm_calm``) so a small perturbation starts in a narrow
    dispatch bucket; ``i2`` overrides the cold-admission cadence for this
    run (``schedule.adaptive_i2`` scales it with the batch size).
    """

    values: np.ndarray
    psd: np.ndarray
    is_hot: np.ndarray
    calm: np.ndarray | None = None
    i2: int | None = None


class EdgeData(NamedTuple):
    """Device-resident dynamic state of the tiled layout — everything a
    delta batch can change without changing tile geometry. Passed as a
    traced argument to every jitted engine function (NOT closed over), so
    in-place streaming mutation re-uses the compiled executable."""

    src: jax.Array  # (n_tiles, TILE) int32
    dstl: jax.Array  # (n_tiles, TILE) int32
    w: jax.Array  # (n_tiles, TILE) float32
    valid: jax.Array  # (n_tiles, TILE) bool
    cov: jax.Array  # (n_tiles, S) bool — sub-block dst coverage per tile
    aux: jax.Array  # (n,) float32 per-vertex constant (e.g. out-degree)


def tile_coverage(dst_local, valid, subblocks: int,
                  block_size: int | None = None) -> np.ndarray:
    """(n_tiles, S) bool: which of a block's S sub-ranges each tile's VALID
    destinations land in. Coverage is a function of tile structure only
    (dstl/valid), not of values, so it is computed host-side once per
    epoch — and per touched row on streaming commits — instead of by a
    scatter inside every traced tile visit. At S = 1 it degenerates to
    'tile has any valid slot' (unused by the flat trace)."""
    d = np.asarray(dst_local)
    v = np.asarray(valid, dtype=bool)
    if subblocks <= 1:
        return v.any(axis=1, keepdims=True)
    sub = block_size // subblocks
    cov = np.zeros((d.shape[0], subblocks), dtype=bool)
    ii, jj = np.nonzero(v)
    cov[ii, d[ii, jj] // sub] = True
    return cov


def edge_data(store: TiledStorage, aux, subblocks: int = 1,
              block_size: int | None = None) -> EdgeData:
    return EdgeData(src=jnp.asarray(store.src),
                    dstl=jnp.asarray(store.dst_local),
                    w=jnp.asarray(store.w), valid=jnp.asarray(store.valid),
                    cov=jnp.asarray(tile_coverage(
                        store.dst_local, store.valid, subblocks,
                        block_size)),
                    aux=jnp.asarray(aux))


# -- adaptive-schedule decision helpers --------------------------------------
# Module-level so the multi-lane query engine (repro.serve.lanes) applies the
# SAME decisions as the single-program engine — the single-lane service path
# reproduces the engine trajectory exactly because these are shared, not
# reimplemented.
def inner_depths(cfg: EngineConfig, width: int) -> np.ndarray:
    """Per-slot Gauss-Seidel depth for the hot sweep, by PSD rank: slot 0
    (the hottest block) runs the full ``hot_inner_iters``, halving per rank
    down to 1 — deep async iteration is spent where the delta mass is, not
    on every scheduled block. Dense mode keeps the constant depth. Depth
    depends only on the absolute slot index, so host and fused ranks (and
    every width bucket) agree."""
    t = max(cfg.hot_inner_iters, 1)
    if not cfg.adaptive:
        return np.full(width, t, dtype=np.int32)
    return np.maximum(1, t >> np.minimum(np.arange(width), 30)) \
        .astype(np.int32)


def dispatch_width(cfg: EngineConfig, ladder: list[int], active: int,
                   psd_host: np.ndarray) -> int:
    """Dispatch bucket for the live active-set size (non-retired blocks),
    chosen by the host at repartition boundaries. While an UNSEEN re-heat
    wave is still in flight the bucket gets 2x headroom: unprocessed
    blocks are about to re-arm their neighbourhood through the staleness
    coupling, and a bucket that exactly covers today's active set
    throttles that propagation (measured: more supersteps at barely-lower
    per-superstep cost). Once the wave has passed, the active count is
    trustworthy and the tail narrows for real."""
    if not cfg.adaptive:
        return cfg.width
    if bool((psd_host >= state_lib.UNSEEN).any()):
        active *= 2
    return pick_width(ladder, active)


def acct_table(plan: PartitionPlan, edge_counts: np.ndarray) -> np.ndarray:
    """(P, len(COUNTER_FIELDS)) host-side accounting row per schedule of a
    block: [vertices updated, edges processed, 1 load, bytes loaded]. The
    device only counts schedules per block (small exact int32s); the host
    multiplies through this table at flush time, so metric totals stay
    exact at any scale. ``edge_counts`` is the CALLER'S live per-block
    count (warm streaming runs and pinned query epochs bill mutated blocks
    at their size when the run started, not the plan snapshot)."""
    acct = np.zeros((plan.num_blocks, 4), dtype=np.int64)
    for b in range(plan.num_blocks):
        lo, hi = plan.block_range(b)
        e = int(edge_counts[b])
        acct[b] = (hi - lo, e, 1, block_io_bytes(e, plan.block_size))
    return acct


# -- per-superstep trace timeline --------------------------------------------
# Column layout of the traced chunk's history buffers (RunResult.timeline
# keys): the four COUNTER_FIELDS deltas, then hot dispatches / retired
# blocks / UNSEEN blocks (int32 — the per-superstep deltas are chunk-local
# and small; the aggregate totals still flow through the int64 host acct
# path), and the block-folded finite PSD sum/max (float32).
TIMELINE_INT_COLS = COUNTER_FIELDS + ("hot_loads", "retired", "unseen")
TIMELINE_FLOAT_COLS = ("psd_sum", "psd_max")


def _hist_cap(span: int) -> int:
    """Power-of-two history-buffer capacity covering a traced chunk span.
    Chunk spans follow the repartition cadence, which GROWS 1.5x per
    boundary — keying the traced executable on the raw span would compile
    one variant per boundary. Pow2 bucketing (floor 16) keeps the
    executable count logarithmic in the final interval while the chunk
    boundaries themselves stay exactly where the untraced run puts them
    (capacity never changes the trajectory, only the buffer shape)."""
    return max(16, 1 << max(span - 1, 1).bit_length())


def _combine_local(program: VertexProgram, msg, dst_local, block_size,
                   use_pallas: bool):
    if program.combine == "sum":
        if use_pallas:
            from repro.kernels import ops as kops
            return kops.edge_block_sum(msg, dst_local, block_size)
        return jnp.zeros(block_size, jnp.float32).at[dst_local].add(msg)
    if program.combine == "min":
        if use_pallas:
            from repro.kernels import ops as kops
            return kops.edge_block_min(msg, dst_local, block_size,
                                       float(program.identity))
        return jnp.full(block_size, program.identity).at[dst_local].min(msg)
    if use_pallas:
        from repro.kernels import ops as kops
        return kops.edge_block_max(msg, dst_local, block_size,
                                   float(program.identity))
    return jnp.full(block_size, program.identity).at[dst_local].max(msg)


def make_block_processor(program: VertexProgram, store: EdgeStorage, aux,
                         block_size: int, n_live: int, n_total: int,
                         use_pallas: bool):
    """Returns (process_one, gids): the pull-mode update for one block row of
    one storage group. Shared by the local and shard_map engines."""
    src = jnp.asarray(store.src)
    dstl = jnp.asarray(store.dst_local)
    ew = jnp.asarray(store.w)
    evalid = jnp.asarray(store.valid)
    gids = jnp.asarray(store.block_ids, dtype=jnp.int32)
    c = block_size

    def process_one(values, row):
        e_src = src[row]
        msg = program.edge_map(values[e_src], aux[e_src], ew[row])
        msg = jnp.where(evalid[row], msg, program.identity)
        agg = _combine_local(program, msg, dstl[row], c, use_pallas)
        base = gids[row] * c
        old = lax.dynamic_slice(values, (base,), (c,))
        new = program.apply(old, agg, n_total)
        vmask = (base + jnp.arange(c)) < n_live
        new = jnp.where(vmask, new, old)
        delta = jnp.where(vmask, program.sd_delta(old, new), 0.0)
        cnt = jnp.maximum(vmask.sum(), 1)
        # (mean, max) per-block deltas: mean is the paper's PSD; max feeds the
        # sound staleness bound (mean-based coupling under-estimates when the
        # delta mass is concentrated on a hub).
        return base, new, delta.sum() / cnt, delta.max()

    def process_iterated(values, row, t_inner):
        """Asynchronous hot mode, TPU-native: the block's edge slice is
        VMEM-resident, so re-applying the block update t_inner times costs
        ONE partition load but advances intra-block dependency chains
        t_inner hops (the paper's per-vertex async propagation, at block
        granularity). Writes only within the block's own range."""
        base = gids[row] * c
        old = lax.dynamic_slice(values, (base,), (c,))

        def inner(_, vals):
            _, new, _, _ = process_one(vals, row)
            return lax.dynamic_update_slice(vals, new, (base,))

        vals2 = lax.fori_loop(0, t_inner, inner, values)
        newb = lax.dynamic_slice(vals2, (base,), (c,))
        vmask = (base + jnp.arange(c)) < n_live
        delta = jnp.where(vmask, program.sd_delta(old, newb), 0.0)
        cnt = jnp.maximum(vmask.sum(), 1)
        return base, newb, delta.sum() / cnt, delta.max()

    return process_one, process_iterated, gids


def make_tiled_processor(program: VertexProgram, store: TiledStorage,
                         block_size: int, n_live: int, n_total: int,
                         use_pallas: bool, subblocks: int = 1):
    """Block processor over the unified tiled layout: ``row`` is the GLOBAL
    block id and the per-block work is a fori over that block's tile rows,
    so compute scales with the block's true edge count rather than a shared
    padded capacity. Only the tile GEOMETRY (tile_start/tile_cnt) is closed
    over; the edge arrays and aux arrive per call as an :class:`EdgeData`,
    so streaming mutations never invalidate the trace.

    With ``subblocks = S > 1`` the processors take a ``sub_act`` (S,) bool
    mask (which of the block's S equal sub-ranges are live) and return
    PER-SUB-BLOCK (S,) mean/max deltas. Masked sub-ranges keep their old
    values and report no delta, and a tile whose valid destinations all
    land in masked sub-ranges is skipped entirely (tiles are CSC-ordered,
    so each covers a narrow dst range — this is where a one-hot-sub block
    stops paying its whole edge slice). ``sub_act=None`` (the S = 1 path)
    traces to EXACTLY the flat per-block code — bitwise parity with the
    non-hierarchical engine is by construction, not by rounding luck."""
    tile_start = jnp.asarray(store.tile_start, dtype=jnp.int32)
    tile_cnt = jnp.asarray(store.tile_cnt, dtype=jnp.int32)
    gids = jnp.arange(store.num_blocks, dtype=jnp.int32)
    c = block_size
    sub = c // max(subblocks, 1)

    if program.combine == "sum":
        agg0 = jnp.zeros(c, jnp.float32)
        merge = jnp.add
    elif program.combine == "min":
        agg0 = jnp.full(c, program.identity)
        merge = jnp.minimum
    else:
        agg0 = jnp.full(c, program.identity)
        merge = jnp.maximum

    # under use_pallas the whole per-block update (gather → edge_map →
    # combine → apply, sub_act-masked in-kernel) is ONE fused pallas_call;
    # the dense fori below stays the bitwise reference and its trace is
    # untouched (the golden jaxprs pin it)
    fused = None
    if use_pallas:
        from repro.kernels import ops as kops
        fused = kops.make_block_sweep(program, store, c, n_total,
                                      subblocks=subblocks)

    def process_one(ed: EdgeData, values, row, sub_act=None):
        if fused is not None:
            new = fused(ed, values, row, sub_act)
            base = row * c
            old = lax.dynamic_slice(values, (base,), (c,))
        else:
            t0 = tile_start[row]

            def tile_compute(t, agg):
                r = t0 + t
                e_src = ed.src[r]
                msg = program.edge_map(values[e_src], ed.aux[e_src],
                                       ed.w[r])
                msg = jnp.where(ed.valid[r], msg, program.identity)
                return merge(agg,
                             _combine_local(program, msg, ed.dstl[r], c,
                                            use_pallas))

            if sub_act is None:
                tile_body = tile_compute
            else:
                def tile_body(t, agg):
                    r = t0 + t
                    # skip the gather/combine when every sub-range this
                    # tile's valid destinations cover (ed.cov —
                    # precomputed per epoch, maintained per touched row by
                    # streaming commits) is masked: identity branch — the
                    # vmapped cold sweep lowers this to a select, the
                    # sequential hot sweep skips for real
                    return lax.cond((ed.cov[r] & sub_act).any(),
                                    lambda a: tile_compute(t, a),
                                    lambda a: a, agg)

            agg = lax.fori_loop(0, tile_cnt[row], tile_body, agg0)
            base = row * c
            old = lax.dynamic_slice(values, (base,), (c,))
            new = program.apply(old, agg, n_total)
        vmask = (base + jnp.arange(c)) < n_live
        if sub_act is None:
            new = jnp.where(vmask, new, old)
            delta = jnp.where(vmask, program.sd_delta(old, new), 0.0)
            cnt = jnp.maximum(vmask.sum(), 1)
            return base, new, delta.sum() / cnt, delta.max()
        keep = vmask & jnp.repeat(sub_act, sub)
        new = jnp.where(keep, new, old)
        delta = jnp.where(keep, program.sd_delta(old, new), 0.0)
        dsub = delta.reshape(subblocks, sub)
        cnt = jnp.maximum(vmask.reshape(subblocks, sub).sum(axis=1), 1)
        return base, new, dsub.sum(axis=1) / cnt, dsub.max(axis=1)

    def process_iterated(ed: EdgeData, values, row, t_inner, sub_act=None):
        """Asynchronous hot mode (see make_block_processor): t_inner
        block-local Gauss-Seidel passes per partition load."""
        base = row * c
        old = lax.dynamic_slice(values, (base,), (c,))

        def inner(_, vals):
            _, new, _, _ = process_one(ed, vals, row, sub_act)
            return lax.dynamic_update_slice(vals, new, (base,))

        vals2 = lax.fori_loop(0, t_inner, inner, values)
        newb = lax.dynamic_slice(vals2, (base,), (c,))
        vmask = (base + jnp.arange(c)) < n_live
        if sub_act is None:
            delta = jnp.where(vmask, program.sd_delta(old, newb), 0.0)
            cnt = jnp.maximum(vmask.sum(), 1)
            return base, newb, delta.sum() / cnt, delta.max()
        keep = vmask & jnp.repeat(sub_act, sub)
        delta = jnp.where(keep, program.sd_delta(old, newb), 0.0)
        dsub = delta.reshape(subblocks, sub)
        cnt = jnp.maximum(vmask.reshape(subblocks, sub).sum(axis=1), 1)
        return base, newb, dsub.sum(axis=1) / cnt, dsub.max(axis=1)

    return process_one, process_iterated, gids


def make_lane_processor(program: LaneProgram, store: TiledStorage,
                        block_size: int, n_live: int, n_total: int,
                        subblocks: int = 1, use_pallas: bool = False):
    """Lane-axis generalization of :func:`make_tiled_processor`: vertex
    values are ``(values_len, L)`` and one pass over a block's edge tiles
    advances every lane — the edge slice (src ids, weights, validity) is
    read ONCE per tile and the gather/combine/apply math is vectorized
    over the lane axis, so L queries share each partition load. The lane
    count is taken from the runtime shapes (jit specializes per L; the
    query service pads batches to a fixed L so one executable serves the
    steady state). ``vconst`` is the per-vertex-per-lane constant matrix
    (personalized restart vectors); families that ignore it get zeros.
    Per-block results are per-lane vectors: (base, new (C, L), mean-delta
    (L,), max-delta (L,)) — the (P, L) PSD state the lane superstep
    schedules on. With ``subblocks = S > 1`` the processors additionally
    take a shared (S,) ``sub_act`` mask (lane-folded: a sub-range is live
    if ANY running lane prices it over the floor) and the deltas grow a
    leading sub-block axis — (S, L) — mirroring
    :func:`make_tiled_processor`; ``sub_act=None`` is the exact flat
    path."""
    tile_start = jnp.asarray(store.tile_start, dtype=jnp.int32)
    tile_cnt = jnp.asarray(store.tile_cnt, dtype=jnp.int32)
    gids = jnp.arange(store.num_blocks, dtype=jnp.int32)
    c = block_size
    sub = c // max(subblocks, 1)

    if program.combine == "sum":
        def combine(msg, dstl, nl):
            return jnp.zeros((c, nl), jnp.float32).at[dstl].add(msg)
        merge = jnp.add
    elif program.combine == "min":
        def combine(msg, dstl, nl):
            return jnp.full((c, nl), program.identity).at[dstl].min(msg)
        merge = jnp.minimum
    else:
        def combine(msg, dstl, nl):
            return jnp.full((c, nl), program.identity).at[dstl].max(msg)
        merge = jnp.maximum

    # the lane-batched fused kernel: one pallas_call per block sweeps all
    # L lanes with the (C, L) accumulator VMEM-resident and the sum
    # combine as a (C, E_t) @ (E_t, L) MXU matmul — this is the fix for
    # the scatter-bound PPR lane combine below
    fused = None
    if use_pallas:
        from repro.kernels import ops as kops
        fused = kops.make_block_sweep(program, store, c, n_total,
                                      subblocks=subblocks, lanes=True)

    def process_one(ed: EdgeData, values, vconst, row, sub_act=None):
        nl = values.shape[1]
        if fused is not None:
            new = fused(ed, values, vconst, row, sub_act)
            base = row * c
            old = lax.dynamic_slice(values, (base, 0), (c, nl))
            vmask = (base + jnp.arange(c)) < n_live
            if sub_act is None:
                new = jnp.where(vmask[:, None], new, old)
                delta = jnp.where(vmask[:, None],
                                  program.sd_delta(old, new), 0.0)
                cnt = jnp.maximum(vmask.sum(), 1)
                return (base, new, delta.sum(axis=0) / cnt,
                        delta.max(axis=0))
            keep = vmask & jnp.repeat(sub_act, sub)
            new = jnp.where(keep[:, None], new, old)
            delta = jnp.where(keep[:, None], program.sd_delta(old, new),
                              0.0)
            dsub = delta.reshape(subblocks, sub, nl)
            cnt = jnp.maximum(vmask.reshape(subblocks, sub).sum(axis=1), 1)
            return (base, new, dsub.sum(axis=1) / cnt[:, None],
                    dsub.max(axis=1))
        t0 = tile_start[row]
        if program.combine == "sum":
            agg0 = jnp.zeros((c, nl), jnp.float32)
        else:
            agg0 = jnp.full((c, nl), program.identity)

        def tile_compute(t, agg):
            r = t0 + t
            e_src = ed.src[r]
            msg = program.edge_map(values[e_src], ed.aux[e_src], ed.w[r])
            msg = jnp.where(ed.valid[r][:, None], msg, program.identity)
            return merge(agg, combine(msg, ed.dstl[r], nl))

        if sub_act is None:
            tile_body = tile_compute
        else:
            def tile_body(t, agg):
                r = t0 + t
                return lax.cond((ed.cov[r] & sub_act).any(),
                                lambda a: tile_compute(t, a),
                                lambda a: a, agg)

        agg = lax.fori_loop(0, tile_cnt[row], tile_body, agg0)
        base = row * c
        old = lax.dynamic_slice(values, (base, 0), (c, nl))
        vc = lax.dynamic_slice(vconst, (base, 0), (c, nl))
        new = program.apply(old, agg, vc, n_total)
        vmask = (base + jnp.arange(c)) < n_live
        if sub_act is None:
            new = jnp.where(vmask[:, None], new, old)
            delta = jnp.where(vmask[:, None], program.sd_delta(old, new),
                              0.0)
            cnt = jnp.maximum(vmask.sum(), 1)
            return base, new, delta.sum(axis=0) / cnt, delta.max(axis=0)
        keep = vmask & jnp.repeat(sub_act, sub)
        new = jnp.where(keep[:, None], new, old)
        delta = jnp.where(keep[:, None], program.sd_delta(old, new), 0.0)
        dsub = delta.reshape(subblocks, sub, nl)
        cnt = jnp.maximum(vmask.reshape(subblocks, sub).sum(axis=1), 1)
        return (base, new, dsub.sum(axis=1) / cnt[:, None],
                dsub.max(axis=1))

    def process_iterated(ed: EdgeData, values, vconst, row, t_inner,
                         sub_act=None):
        """Asynchronous hot mode (see make_block_processor): t_inner
        block-local Gauss-Seidel passes per partition load, all lanes."""
        nl = values.shape[1]
        base = row * c
        old = lax.dynamic_slice(values, (base, 0), (c, nl))

        def inner(_, vals):
            _, new, _, _ = process_one(ed, vals, vconst, row, sub_act)
            return lax.dynamic_update_slice(vals, new, (base, 0))

        vals2 = lax.fori_loop(0, t_inner, inner, values)
        newb = lax.dynamic_slice(vals2, (base, 0), (c, nl))
        vmask = (base + jnp.arange(c)) < n_live
        if sub_act is None:
            delta = jnp.where(vmask[:, None], program.sd_delta(old, newb),
                              0.0)
            cnt = jnp.maximum(vmask.sum(), 1)
            return base, newb, delta.sum(axis=0) / cnt, delta.max(axis=0)
        keep = vmask & jnp.repeat(sub_act, sub)
        delta = jnp.where(keep[:, None], program.sd_delta(old, newb), 0.0)
        dsub = delta.reshape(subblocks, sub, nl)
        cnt = jnp.maximum(vmask.reshape(subblocks, sub).sum(axis=1), 1)
        return (base, newb, dsub.sum(axis=1) / cnt[:, None],
                dsub.max(axis=1))

    return process_one, process_iterated, gids


class StructureAwareEngine:
    """Paper pipeline: build plan -> iterate (schedule, process, repartition)."""

    def __init__(self, graph: Graph, program: VertexProgram,
                 config: EngineConfig = EngineConfig()):
        self.program = program
        self.config = config
        g = symmetrize(graph) if program.needs_symmetric else graph
        self.plan = build_plan(
            g, block_size=config.block_size, alpha=config.alpha,
            sample_frac=config.sample_frac, hot_ratio=config.hot_ratio,
            seed=config.seed, tile_slack=config.tile_slack,
            spare_tiles=config.spare_tiles,
            keep_dead=config.keep_dead_blocks,
            subblocks=config.subblocks)
        vals0, aux0 = program.init(g)  # original ids ...
        self.values0 = vals0[self.plan.order]  # ... permuted to plan order
        self.aux = jnp.asarray(aux0[self.plan.order])
        self._init_dead()
        # Pad the value vector so every block's (base, block_size) slice is
        # in-bounds: lax.dynamic_slice CLAMPS out-of-range starts, which would
        # silently corrupt the last block's writes.
        p = self.plan
        self._values_len = max(p.num_blocks * p.block_size, p.graph.n)
        self.values0 = self.pad_values(self.values0)
        # Per-block true edge counts: a MUTABLE copy (streaming updates it);
        # feeds the exact metric accounting and the bytes cost model.
        self.edge_counts = np.array(p.unified.edges, dtype=np.int64)
        self._ed = edge_data(p.unified, self.aux, self.config.subblocks,
                             p.block_size)
        self._block_affects = self._build_block_affects()
        self._coupling = self._build_coupling_matrix()
        self._coupling_dev = jnp.asarray(self._coupling)
        self._post = jax.jit(self._make_post())
        self._fns: dict = {}
        # descending dispatch-width buckets; the host picks per boundary
        self._ladder = (width_ladder(config.width, config.min_width)
                        if config.adaptive else [config.width])
        # pad block for dispatch slots beyond the take counts: the sweeps
        # still compute padded slots, so it is the cheapest block's id —
        # and under an out-of-core budget it is pinned resident
        tile_cnt = p.unified.tile_cnt
        self.pad_id = int(np.argmin(tile_cnt)) if tile_cnt.size else 0
        # activity state of the last completed run (the epoch-persistence
        # record; see repro.ooc.snapshot)
        self.last_psd: np.ndarray | None = None
        self.last_calm: np.ndarray | None = None
        self.spill = None
        if (config.resident_blocks is not None
                and config.resident_blocks < p.num_blocks):
            from repro.ooc.store import SpillStore  # avoid import cycle
            self.spill = SpillStore(self, config.resident_blocks,
                                    directory=config.spill_dir)

    # -- one-time host preprocessing ---------------------------------------
    def _init_dead(self):
        """Dead partition: processed once at start (§3.2) — apply() with the
        identity aggregate, after which these vertices are final."""
        p = self.plan
        if p.n_dead == 0:
            return
        dead = slice(p.n_live, p.graph.n)
        old = jnp.asarray(self.values0[dead])
        agg = jnp.full(p.n_dead, 0.0 if self.program.combine == "sum"
                       else self.program.identity, jnp.float32)
        self.values0 = np.array(self.values0)
        self.values0[dead] = np.asarray(
            self.program.apply(old, agg, p.graph.n))

    def _build_block_affects(self) -> list[tuple[np.ndarray, np.ndarray]]:
        """block j -> (target blocks, coupling weights).

        Soundness: with v = MAX per-vertex delta in block j, the delta mass
        entering block b is <= v * sum_{u in j} min(edges(u->b)/outdeg(u), 1)
        <= v * min(W_jb, C_j), so b's mean-PSD can move by at most
        decay * v * min(W_jb, C) / C. For min/max programs improvements
        propagate undiminished and unsplit, so the coupling is 1 on every
        reachable target (correctness over tightness)."""
        p = self.plan
        g = p.graph
        c = p.block_size
        out: list[tuple[np.ndarray, np.ndarray]] = []
        for b in range(p.num_blocks):
            lo, hi = p.block_range(b)
            dsts = g.out_dst[g.out_indptr[lo]:g.out_indptr[hi]]
            blocks, counts = np.unique(dsts // c, return_counts=True)
            keep = blocks < p.num_blocks
            blocks, counts = blocks[keep], counts[keep]
            out.append((blocks.astype(np.int64), counts.astype(np.int64)))
        return out

    def _build_coupling_matrix(self) -> np.ndarray:
        """Dense (P, P) staleness-coupling matrix (decay folded in): the
        device-side bump is the max-product matvec
        ``bump_b = max_j dmax_j * K[j, b]``. With sub-blocks the counts
        (and hence K) grow a destination-sub axis — (P, P, S) — so the
        bump lands per sub-range: ``bump_{b,s} = max_j dmax_j * K[j, b,
        s]``. The underlying edge-count matrix is kept as
        ``self.coupling_counts`` — the truth the streaming subsystem
        maintains incrementally."""
        p = self.plan
        s = self.config.subblocks
        if s == 1:
            w = np.zeros((p.num_blocks, p.num_blocks), dtype=np.int64)
            for j, (tgt, counts) in enumerate(self._block_affects):
                w[j, tgt] = counts
        else:
            g, c, ks = p.graph, p.block_size, p.sub_size
            w = np.zeros((p.num_blocks, p.num_blocks, s), dtype=np.int64)
            for j in range(p.num_blocks):
                lo, hi = p.block_range(j)
                dsts = g.out_dst[g.out_indptr[lo]:g.out_indptr[hi]]
                d = dsts[dsts // c < p.num_blocks]  # drop the dead tail
                np.add.at(w[j], (d // c, (d % c) // ks), 1)
        self.coupling_counts = w
        return coupling_from_counts(w, self.program, p.block_size)

    def _make_post(self):
        eps = self.config.stale_eps
        floor = self._psd_floor()

        def post(coupling, psd, dmax, calm):
            """Consume dmax: re-arm downstream blocks, then reset. Also
            advances the block-local convergence counters: a superstep
            spent under the pruning floor increments ``calm``; any PSD at
            or over the floor (own activity OR an incoming bump) resets it
            — the retire/re-arm hysteresis of the adaptive active set.

            Polymorphic over the sub-block axis: with (P, S) state the
            outgoing signal stays block-granular (the block's max
            sub-delta — deltas anywhere in the source block can reach any
            of its out-edges) but the incoming bump is SUB-resolved
            through the (P, P, S) coupling: only the target sub-ranges
            that receive edges from the moving block re-arm. Calm then
            advances per sub-block. 1-D state traces to the exact flat
            path (the retire/re-arm unit test drives it directly)."""
            d = jnp.where(dmax > eps, dmax, 0.0)
            if psd.ndim == 2:
                dblk = d.max(axis=1)
                if coupling.ndim == 3:  # (P, P, S): sub-resolved bump
                    bump = jnp.max(dblk[:, None, None] * coupling, axis=0)
                else:  # S = 1 keeps the flat (P, P) coupling: exact old path
                    bump = jnp.max(dblk[:, None] * coupling, axis=0)[:, None]
                psd = jnp.maximum(psd, jnp.minimum(bump, 1e29))
            else:
                bump = jnp.max(d[:, None] * coupling, axis=0)
                psd = jnp.maximum(psd, jnp.minimum(bump, 1e29))
            calm = jnp.where(psd < floor, calm + 1, 0).astype(jnp.int32)
            return psd, jnp.zeros_like(dmax), calm
        return post

    def _psd_floor(self) -> float:
        """Per-block pruning floor (t2/P): skipping blocks below it is safe
        — if every block were below it, SUM(psd) < t2 and we are converged.
        The ONE definition shared by the scheduler's live test and the
        calm/retire counters, so they can never disagree."""
        return self.config.t2 / max(self.plan.num_blocks, 1)

    def _inner_depths(self, width: int) -> np.ndarray:
        return inner_depths(self.config, width)

    def _pick_width(self, active: int, psd_host: np.ndarray) -> int:
        return dispatch_width(self.config, self._ladder, active, psd_host)

    def _active_count(self, calm_host: np.ndarray) -> int:
        """Blocks still in the active set: a block is live while ANY of its
        sub-blocks is (calm is (P, S); 1-D input keeps the flat meaning)."""
        if not self.config.adaptive:
            return self.plan.num_blocks
        live = np.asarray(calm_host) < self.config.retire_after
        if live.ndim == 2:
            live = live.any(axis=-1)
        return int(live.sum())

    def _subblocks_retired(self, calm_host: np.ndarray) -> int:
        """Sub-blocks retired at end of run (0 on the dense path, where
        calm never gates anything — mirrors blocks_retired)."""
        if not self.config.adaptive:
            return 0
        return int((np.asarray(calm_host) >=
                    self.config.retire_after).sum())

    def _acct_table(self) -> np.ndarray:
        return acct_table(self.plan, self.edge_counts)

    # -- streaming hooks -----------------------------------------------------
    def edge_snapshot(self) -> EdgeData:
        """Device-side DEEP COPY of the current dynamic edge state. The
        incremental commit path mutates the resident buffers through
        DONATED scatters, which invalidates any outstanding reference to
        them — a caller that must keep reading this epoch across future
        commits (the query service's snapshot isolation) copies first.
        O(m) device bytes, zero host traffic — except under an
        out-of-core budget, where the snapshot's spilled holes are
        materialized from the spill tier's truth (residency unchanged):
        a pinned epoch must survive the eviction of its blocks."""
        ed = EdgeData(*(jnp.array(a) for a in self._ed))
        if self.spill is not None:
            ed = self.spill.materialize(ed)
        return ed

    @property
    def edge_state(self) -> EdgeData:
        """The LIVE device-resident dynamic edge state. Borrow only where
        no incremental commit can intervene; across commits, take
        :meth:`edge_snapshot` instead (the commits donate these buffers)."""
        return self._ed

    def set_edge_data(self, *, src=None, dst_local=None, w=None, valid=None,
                      aux=None) -> None:
        """Swap (parts of) the device-resident dynamic edge state with a
        FULL re-upload — the whole-array fallback of the row-granular
        ``update_edge_rows`` / ``update_aux`` path the streaming engine
        uses (kept as external API for callers that rebuilt their arrays
        wholesale). Shapes must match the compiled epoch — a geometry
        change needs a new engine, not new arrays."""
        ed = self._ed
        new_dstl = (jnp.asarray(dst_local, jnp.int32)
                    if dst_local is not None else ed.dstl)
        new_valid = (jnp.asarray(valid, bool) if valid is not None
                     else ed.valid)
        cov = ed.cov
        if dst_local is not None or valid is not None:
            cov = jnp.asarray(tile_coverage(
                np.asarray(new_dstl), np.asarray(new_valid),
                self.config.subblocks, self.plan.block_size))
        new = EdgeData(
            src=jnp.asarray(src, jnp.int32) if src is not None else ed.src,
            dstl=new_dstl,
            w=jnp.asarray(w, jnp.float32) if w is not None else ed.w,
            valid=new_valid, cov=cov,
            aux=jnp.asarray(aux, jnp.float32) if aux is not None else ed.aux)
        for name in EdgeData._fields:
            if getattr(new, name).shape != getattr(ed, name).shape:
                raise ValueError(
                    f"EdgeData.{name} shape {getattr(new, name).shape} != "
                    f"compiled epoch shape {getattr(ed, name).shape}")
        self._ed = new
        if aux is not None:
            self.aux = new.aux

    def set_coupling(self, coupling: np.ndarray) -> None:
        """Full (P, P) coupling swap — whole-matrix fallback of
        ``update_coupling_rows``."""
        if coupling.shape != self._coupling.shape:
            raise ValueError("coupling shape changed within an epoch")
        self._coupling = np.asarray(coupling, dtype=np.float32)
        self._coupling_dev = jnp.asarray(self._coupling)

    # -- incremental streaming commits (sub-O(m) host->device path) ----------
    # The scatter functions are jitted with DONATED destination buffers, so
    # the device-resident state is updated in place and the host->device
    # payload is only the touched rows/entries — never the full arrays the
    # set_edge_data / set_coupling path re-uploads. Each scatter runs in
    # FIXED-SIZE chunks (one compiled variant per scatter type — per-batch
    # index counts never trigger a recompile), with the final partial
    # chunk padded by duplicates of entry 0 (identical payload, so the
    # duplicate scatter is order-independent). The returned byte counts
    # bill the chunked transfer that actually crosses to the device,
    # indices included.
    _ROW_CHUNK = 16  # tile rows per scatter call (~100KB payload)
    _AUX_CHUNK = 256  # aux entries per scatter call
    _COUPLING_CHUNK = 16  # coupling rows per scatter call

    @one_executable_per("scatter-type")
    def _chunked_scatter(self, key: str, arrays: tuple, idx: np.ndarray,
                         payloads: list, chunk: int) -> tuple[tuple, int]:
        """Scatter ``payloads`` into ``arrays`` at ``idx`` in fixed-size
        chunks through one cached donated jit. Returns (new arrays, padded
        entry count)."""
        k = int(idx.size)
        pk = -(-k // chunk) * chunk
        if pk != k:
            pad = pk - k
            idx = np.concatenate([idx, np.full(pad, idx[0], idx.dtype)])
            payloads = [np.concatenate([p, np.repeat(p[:1], pad, axis=0)])
                        for p in payloads]
        fn = self._fns.get(key)
        if fn is None:
            na = len(arrays)

            def scatter(*args):
                arrs, r, ps = args[:na], args[na], args[na + 1:]
                return tuple(a.at[r].set(p) for a, p in zip(arrs, ps))

            fn = jax.jit(scatter, donate_argnums=tuple(range(na)))
            self._fns[key] = fn
        for at in range(0, pk, chunk):
            arrays = fn(*arrays, jnp.asarray(idx[at:at + chunk]),
                        *(jnp.asarray(p[at:at + chunk]) for p in payloads))
        return arrays, pk

    def update_edge_rows(self, rows: np.ndarray, *, src, dst_local, w,
                         valid) -> int:
        """Scatter updated TILE ROWS into the device-resident EdgeData.
        ``rows`` are unified-tile row indices; the payloads are the matching
        (len(rows), TILE) slices. Returns the transferred bytes (chunked
        payload + indices)."""
        rows = np.asarray(rows, dtype=np.int32)
        if rows.size == 0:
            return 0
        ed = self._ed
        cov = tile_coverage(dst_local, valid, self.config.subblocks,
                            self.plan.block_size)
        (ns, nd, nw, nv, nc), pk = self._chunked_scatter(
            "row_scatter", (ed.src, ed.dstl, ed.w, ed.valid, ed.cov), rows,
            [np.asarray(src, np.int32), np.asarray(dst_local, np.int32),
             np.asarray(w, np.float32), np.asarray(valid, bool), cov],
            self._ROW_CHUNK)
        self._ed = EdgeData(src=ns, dstl=nd, w=nw, valid=nv, cov=nc,
                            aux=ed.aux)
        # 4B src + 4B dst offset + 4B w + 1B valid per slot + 1B per
        # sub-block coverage bit + 4B row index
        return pk * (int(ns.shape[1]) * 13 + int(nc.shape[1]) + 4)

    def update_aux(self, idx: np.ndarray, vals: np.ndarray) -> int:
        """Scatter changed per-vertex aux entries into the device-resident
        EdgeData. Returns the transferred bytes (chunked values +
        indices)."""
        idx = np.asarray(idx, dtype=np.int32)
        vals = np.asarray(vals, dtype=np.float32)
        if idx.size == 0:
            return 0
        (new_aux,), pk = self._chunked_scatter(
            "aux_scatter", (self._ed.aux,), idx, [vals], self._AUX_CHUNK)
        self._ed = self._ed._replace(aux=new_aux)
        self.aux = new_aux
        return pk * 8

    def update_coupling_rows(self, rows: np.ndarray,
                             row_vals: np.ndarray) -> int:
        """Replace changed ROWS of the staleness-coupling matrix (host copy
        + donated device scatter) — O(changed_rows * P) payload, not the
        full (P, P) re-upload of ``set_coupling``. Returns the transferred
        bytes (chunked rows + indices)."""
        rows = np.asarray(rows, dtype=np.int32)
        row_vals = np.asarray(row_vals, dtype=np.float32)
        if rows.size == 0:
            return 0
        self._coupling[rows] = row_vals
        (new_c,), pk = self._chunked_scatter(
            "coupling_scatter", (self._coupling_dev,), rows, [row_vals],
            self._COUPLING_CHUNK)
        self._coupling_dev = new_c
        return pk * (int(self._coupling[0].size) * 4 + 4)

    @property
    def values_nbytes(self) -> int:
        """Bytes of one padded warm-values upload."""
        return int(self._values_len * 4)

    def full_upload_bytes(self) -> int:
        """Host->device bytes of a FULL dynamic-state refresh (EdgeData +
        aux + coupling + warm values) — what every delta batch paid before
        the row-granular update path, and the denominator of the streaming
        ``upload_frac``."""
        ed = self._ed
        edge_bytes = sum(int(a.size) * a.dtype.itemsize for a in ed)
        return int(edge_bytes + self._coupling.nbytes + self._values_len * 4)

    def pad_values(self, values_perm: np.ndarray) -> np.ndarray:
        """Pad a permuted (n,) value vector to the engine's value length."""
        pad = self._values_len - values_perm.shape[0]
        if pad:
            return np.concatenate(
                [values_perm, np.zeros(pad, dtype=values_perm.dtype)])
        return values_perm

    # -- jitted block processing -------------------------------------------
    def _processor(self):
        if getattr(self, "_proc", None) is None:
            plan, cfg = self.plan, self.config
            self._proc = make_tiled_processor(
                self.program, plan.unified, plan.block_size,
                plan.n_live, plan.graph.n, cfg.use_pallas,
                subblocks=cfg.subblocks)
        return self._proc

    def _sweeps(self, width: int | None = None):
        """(hot_sweep, cold_sweep): the two dispatch bodies, shared at trace
        time by the host-loop fns and the fused superstep so the semantics
        cannot diverge. Both take (ed, values, psd, dmax, rows, ok) with
        (width,) block-id slots; hot is sequential (async, each block sees
        earlier writes) with a per-rank inner depth, cold reads one
        snapshot (sync)."""
        cfg, plan = self.config, self.plan
        width = cfg.width if width is None else width
        depths = jnp.asarray(self._inner_depths(width))
        process_one, process_iterated, gids = self._processor()
        write_one = self._write_one(plan.block_size)
        subblocks = cfg.subblocks
        floor = self._psd_floor()

        # Sub-block activity masks are derived from the block's OWN psd row
        # at slot entry. Within a superstep the scheduled rows are distinct
        # and each sweep slot writes only its own row, so this equals the
        # pre-superstep psd — the invariant the sb-dispatch accounting in
        # _get_chunk / _run_host relies on. At S = 1 every scheduled block
        # clears the floor (selection pruned it otherwise), so the mask
        # would be all-true; sub_act=None keeps the flat trace instead.
        def hot_sweep(ed, values, psd, dmax, rows, ok):
            def body(i, carry):
                values, psd, dmax = carry
                row = rows[i]
                sub_act = None if subblocks == 1 else psd[row] >= floor
                base, new, psd_val, dmax_val = process_iterated(
                    ed, values, row, depths[i], sub_act)
                return write_one(values, psd, dmax, base, new, psd_val,
                                 dmax_val, gids[row], ok[i], sub_act)
            return lax.fori_loop(0, width, body, (values, psd, dmax))

        def cold_sweep(ed, values, psd, dmax, rows, ok):
            if subblocks == 1:
                bases, news, psd_vals, dmax_vals = jax.vmap(
                    lambda r: process_one(ed, values, r))(rows)
                sub_acts = None
            else:
                sub_acts = psd[rows] >= floor  # (W, S)
                bases, news, psd_vals, dmax_vals = jax.vmap(
                    lambda r, sa: process_one(ed, values, r, sa))(
                        rows, sub_acts)

            def body(i, carry):
                values, psd, dmax = carry
                return write_one(values, psd, dmax, bases[i], news[i],
                                 psd_vals[i], dmax_vals[i],
                                 gids[rows[i]], ok[i],
                                 None if sub_acts is None else sub_acts[i])
            return lax.fori_loop(0, width, body, (values, psd, dmax))

        return hot_sweep, cold_sweep

    @staticmethod
    def _write_one(c):
        def write_one(values, psd, dmax, base, new, psd_val, dmax_val, gid,
                      ok, sub_act=None):
            cur = lax.dynamic_slice(values, (base,), (c,))
            values = lax.dynamic_update_slice(
                values, jnp.where(ok, new, cur), (base,))
            if sub_act is not None:
                # masked sub-blocks were not swept: their psd/calm rows
                # must keep decaying toward retirement, not be overwritten
                # with the masked sweep's zero delta
                psd_val = jnp.where(sub_act, psd_val, psd[gid])
                dmax_val = jnp.where(sub_act, dmax_val, dmax[gid])
            psd = jnp.where(ok, psd.at[gid].set(psd_val), psd)
            dmax = jnp.where(ok, dmax.at[gid].set(dmax_val), dmax)
            return values, psd, dmax
        return write_one

    @one_executable_per("sequential", "width")
    def _get_fn(self, sequential: bool, width: int | None = None) -> Callable:
        width = self.config.width if width is None else width
        key = ("unified", sequential, width)
        if key in self._fns:
            return self._fns[key]
        hot_sweep, cold_sweep = self._sweeps(width)
        fn = jax.jit(hot_sweep if sequential else cold_sweep,
                     donate_argnums=(1, 2, 3))
        self._fns[key] = fn
        return fn

    # -- host-side dispatch (run(fused=False) reference path) ---------------
    def _dispatch(self, values, psd, dmax, block_ids: np.ndarray,
                  sequential: bool, width: int | None = None):
        """Run the selected blocks through the unified processor, padded to
        the given dispatch bucket (the adaptive host loop passes its
        current bucket; default is the configured width). Slot index ==
        PSD rank, which is what the hot sweep's depth ladder keys on."""
        w = self.config.width if width is None else width
        for at in range(0, block_ids.size, w):
            chunk = block_ids[at:at + w]
            rows = np.zeros(w, dtype=np.int32)
            ok = np.zeros(w, dtype=bool)
            rows[:chunk.size] = chunk.astype(np.int32)
            ok[:chunk.size] = True
            fn = self._get_fn(sequential, w)
            values, psd, dmax = fn(self._ed, values, psd, dmax,
                                   jnp.asarray(rows), jnp.asarray(ok))
        return values, psd, dmax

    def _account(self, metrics: Metrics, ids: np.ndarray):
        p = self.plan
        for b in ids:
            lo, hi = p.block_range(int(b))
            e = int(self.edge_counts[int(b)])
            metrics.updates += hi - lo
            metrics.block_loads += 1
            metrics.bytes_loaded += block_io_bytes(e, p.block_size)
            metrics.edges_processed += e

    # -- fused device-resident loop -----------------------------------------
    @one_executable_per("width", "trace_cap")
    def _get_chunk(self, width: int | None = None,
                   trace_cap: int | None = None) -> Callable:
        """Jitted multi-iteration chunk: lax.while_loop over fused
        supersteps (schedule -> hot -> cold -> staleness post -> convergence
        test), stopping at the iteration cap, at convergence, or when the
        schedule goes empty. The host supplies the (constant within a
        chunk) hot/cold labels, the dispatch-width bucket (one compiled
        chunk per bucket — ``width`` keys the cache), and the traced
        cold-admission cadence ``i2``; it consumes one
        psd/calm/counters sync per call.

        ``trace_cap=None`` (the default) is EXACTLY the historical chunk
        — same closure, same trace, byte-identical golden jaxpr. With a
        capacity (a :func:`_hist_cap` pow2 bucket; keys the cache
        alongside ``width``) the carry grows two bounded history buffers
        — ``(cap, len(TIMELINE_INT_COLS))`` int32 and
        ``(cap, len(TIMELINE_FLOAT_COLS))`` float32 — and every
        superstep writes its counter deltas / dispatch stats / PSD fold
        at traced index ``it - it0``. The buffers ride the existing
        boundary sync, so per-superstep resolution costs zero extra host
        round-trips, and the algorithmic carry math is untouched — the
        traced trajectory is bitwise the untraced one."""
        width = self.config.width if width is None else width
        key = ("chunk", width, trace_cap)
        if key in self._fns:
            return self._fns[key]
        cfg, plan = self.config, self.plan
        t2 = cfg.t2
        hot_sweep, cold_sweep = self._sweeps(width)
        post = self._make_post()
        select = make_device_select(
            width=width, cold_frac=cfg.cold_frac,
            min_psd=self._psd_floor(), pad_id=self.pad_id)

        floor = self._psd_floor()

        def superstep(it, i2, ed, coupling, values, psd, dmax, calm, counts,
                      hslots, sbacc, is_hot):
            hot_rows, hot_ok, cold_rows, cold_ok = select(it, i2, psd,
                                                          is_hot)
            # sub-dispatch accounting from the PRE-sweep psd — identical to
            # the sub_act masks the sweeps derive (rows are distinct within
            # a superstep; see _sweeps). At S = 1 every ok block counts 1,
            # so sbacc == block loads and the mean dispatch is exactly 1.0.
            live = (psd >= floor).sum(axis=-1).astype(jnp.int32)
            sbacc = sbacc + jnp.where(hot_ok, live[hot_rows], 0).sum() \
                + jnp.where(cold_ok, live[cold_rows], 0).sum()
            values, psd, dmax = hot_sweep(ed, values, psd, dmax, hot_rows,
                                          hot_ok)
            values, psd, dmax = cold_sweep(ed, values, psd, dmax, cold_rows,
                                           cold_ok)
            counts = counts.at[hot_rows].add(hot_ok.astype(jnp.int32))
            counts = counts.at[cold_rows].add(cold_ok.astype(jnp.int32))
            hslots = hslots + hot_ok.astype(jnp.int32)  # depth-hist feed
            # staleness propagation + calm/retire counter advance
            psd, dmax, calm = post(coupling, psd, dmax, calm)
            scheduled = hot_ok.any() | cold_ok.any()
            return values, psd, dmax, calm, counts, hslots, sbacc, scheduled

        def chunk(ed, coupling, values, psd, dmax, calm, counts, hslots,
                  sbacc, it0, it_end, is_hot, i2):
            def cond(carry):
                it, _, _, _, _, _, _, _, done = carry
                return (it < it_end) & jnp.logical_not(done)

            def body(carry):
                it, values, psd, dmax, calm, counts, hslots, sbacc, _ = \
                    carry
                (values, psd, dmax, calm, counts, hslots, sbacc,
                 scheduled) = superstep(it, i2, ed, coupling, values, psd,
                                        dmax, calm, counts, hslots, sbacc,
                                        is_hot)
                conv = state_lib.converged_device(psd, t2)
                # empty schedule: no iteration happened (host parity: the
                # reference loop breaks before processing)
                it = it + jnp.where(scheduled, 1, 0).astype(it.dtype)
                done = conv | jnp.logical_not(scheduled)
                return (it, values, psd, dmax, calm, counts, hslots, sbacc,
                        done)

            (it, values, psd, dmax, calm, counts, hslots, sbacc,
             _) = lax.while_loop(
                cond, body,
                (it0, values, psd, dmax, calm, counts, hslots, sbacc,
                 jnp.bool_(False)))
            return (it, values, psd, dmax, calm, counts, hslots, sbacc,
                    state_lib.converged_device(psd, t2))

        if trace_cap is None:
            fn = jax.jit(chunk, donate_argnums=(2, 3, 4, 5, 6, 7, 8))
            self._fns[key] = fn
            return fn

        # -- traced variant: bounded per-superstep history in the carry --
        nblocks = plan.num_blocks
        retire = cfg.retire_after
        adaptive = cfg.adaptive

        def superstep_traced(it, it0, i2, ed, coupling, values, psd, dmax,
                             calm, counts, hslots, sbacc, hist_i, hist_f,
                             is_hot, acct):
            # re-derive the slate for the delta accounting: pure repeat of
            # the select inside ``superstep`` (identical inputs), so XLA
            # CSEs it away — and even uncached it could only duplicate
            # work, never change a decision
            hot_rows, hot_ok, cold_rows, cold_ok = select(it, i2, psd,
                                                          is_hot)
            (values, psd, dmax, calm, counts, hslots, sbacc,
             scheduled) = superstep(it, i2, ed, coupling, values, psd,
                                    dmax, calm, counts, hslots, sbacc,
                                    is_hot)
            # per-superstep counter delta through the SAME acct table the
            # host multiplies at the boundary flush: the timeline rows sum
            # exactly to the aggregate Metrics counters by construction
            delta = ((acct[hot_rows]
                      * hot_ok[:, None].astype(jnp.int32)).sum(axis=0)
                     + (acct[cold_rows]
                        * cold_ok[:, None].astype(jnp.int32)).sum(axis=0))
            folded = psd.max(axis=-1)  # block fold of the post-post psd
            finite = folded < state_lib.UNSEEN
            if adaptive:
                live = (calm < retire).any(axis=-1)
                retired = (nblocks - live.sum()).astype(jnp.int32)
            else:
                retired = jnp.int32(0)
            row_i = jnp.concatenate([
                delta.astype(jnp.int32),
                jnp.stack([hot_ok.sum().astype(jnp.int32), retired,
                           (~finite).sum().astype(jnp.int32)])])
            fin = jnp.where(finite, folded, 0.0)
            row_f = jnp.stack([fin.sum(), fin.max()])
            idx = it - it0
            hist_i = lax.dynamic_update_slice(hist_i, row_i[None, :],
                                              (idx, 0))
            hist_f = lax.dynamic_update_slice(hist_f, row_f[None, :],
                                              (idx, 0))
            return (values, psd, dmax, calm, counts, hslots, sbacc,
                    hist_i, hist_f, scheduled)

        def chunk_traced(ed, coupling, values, psd, dmax, calm, counts,
                         hslots, sbacc, it0, it_end, is_hot, i2, acct,
                         hist_i, hist_f):
            def cond(carry):
                return (carry[0] < it_end) & jnp.logical_not(carry[-1])

            def body(carry):
                (it, values, psd, dmax, calm, counts, hslots, sbacc,
                 hist_i, hist_f, _) = carry
                (values, psd, dmax, calm, counts, hslots, sbacc, hist_i,
                 hist_f, scheduled) = superstep_traced(
                    it, it0, i2, ed, coupling, values, psd, dmax, calm,
                    counts, hslots, sbacc, hist_i, hist_f, is_hot, acct)
                conv = state_lib.converged_device(psd, t2)
                it = it + jnp.where(scheduled, 1, 0).astype(it.dtype)
                done = conv | jnp.logical_not(scheduled)
                return (it, values, psd, dmax, calm, counts, hslots,
                        sbacc, hist_i, hist_f, done)

            (it, values, psd, dmax, calm, counts, hslots, sbacc, hist_i,
             hist_f, _) = lax.while_loop(
                cond, body,
                (it0, values, psd, dmax, calm, counts, hslots, sbacc,
                 hist_i, hist_f, jnp.bool_(False)))
            return (it, values, psd, dmax, calm, counts, hslots, sbacc,
                    hist_i, hist_f, state_lib.converged_device(psd, t2))

        fn = jax.jit(chunk_traced,
                     donate_argnums=(2, 3, 4, 5, 6, 7, 8, 14, 15))
        self._fns[key] = fn
        return fn

    def prewarm_buckets(self) -> list[int]:
        """Compile the fused chunk for every dispatch-width bucket with a
        zero-length run (it_end == it0: the while_loop body never fires),
        so a long-lived caller (streaming, benchmarks) never pays a bucket
        compile inside a measured batch/run. Returns the widths warmed."""
        p = self.plan
        ps = (p.num_blocks, self.config.subblocks)
        for wb in self._ladder:
            fn = self._get_chunk(wb)
            fn(self._ed, self._coupling_dev,
               jnp.zeros(self._values_len, jnp.float32),
               jnp.zeros(ps, jnp.float32),
               jnp.zeros(ps, jnp.float32),
               jnp.zeros(ps, jnp.int32),
               jnp.zeros(p.num_blocks, jnp.int32),
               jnp.zeros(wb, jnp.int32), jnp.int32(0), jnp.int32(0),
               jnp.int32(0),
               jnp.zeros(p.num_blocks, dtype=bool),
               jnp.int32(self.config.i2))
        return list(self._ladder)

    # -- main loop ----------------------------------------------------------
    def run(self, max_iterations: int | None = None,
            fused: bool | None = None,
            warm: WarmStart | None = None,
            trace: bool | None = None) -> RunResult:
        """Run to convergence. ``fused`` overrides ``config.fused``:
        True = device-resident chunked loop (host syncs only at repartition
        boundaries), False = reference host-driven loop (one sync per
        iteration, per-iteration history). ``warm`` re-enters from a
        previous fixpoint with only the dirty blocks re-heated.

        ``trace`` captures the per-superstep timeline
        (``RunResult.timeline``) and emits run/chunk/repartition spans +
        superstep counters into the installed :mod:`repro.obs` recorder.
        ``None`` (default) auto-enables tracing exactly when a recorder
        is installed, so long-lived callers (streaming reconvergence,
        serve lanes' sibling engines) inherit the capture without
        plumbing. Values and every algorithmic counter of a traced run
        are bitwise identical to the untraced one (property-tested)."""
        fused = self.config.fused if fused is None else fused
        if trace is None:
            trace = obs_trace.current() is not None
        with obs_trace.span("run", cat="engine", fused=bool(fused),
                            warm=warm is not None) as sp:
            res = (self._run_fused(max_iterations, warm, trace=trace)
                   if fused
                   else self._run_host(max_iterations, warm, trace=trace))
            sp.set(iterations=res.metrics.iterations,
                   converged=res.metrics.converged)
        return res

    def _sub2d(self, a: np.ndarray) -> np.ndarray:
        """Normalize a per-block (P,) state vector to the engine's (P, S)
        layout by replicating across sub-blocks (identity content at
        S = 1; for S > 1 a block-granular seed arms/retires all of the
        block's sub-ranges — the sound reading of a flat input)."""
        a = np.asarray(a)
        if a.ndim == 2:
            return a
        return np.repeat(a[:, None], self.config.subblocks, axis=1)

    def _start_state(self, warm: WarmStart | None):
        """(values, psd, rep, calm, i2): the start state of a run. Cold
        runs start fully active (calm 0 everywhere, configured cadence);
        warm runs may seed retired calm counters and a delta-scaled
        cadence (ignored when adaptive is off). psd/calm are (P, S)
        device state; flat (P,) warm seeds are replicated per sub-block."""
        cfg, p = self.config, self.plan
        calm0 = np.zeros((p.num_blocks, cfg.subblocks), dtype=np.int32)
        if warm is None:
            mode = ("barrier" if self.program.monotone_cooling
                    else "universal")
            rep = RepartitionState.create(
                p.num_blocks, p.barrier_block, mode,
                interval=cfg.repartition_interval,
                growth=cfg.repartition_growth)
            return (jnp.asarray(self.values0),
                    jnp.asarray(state_lib.init_psd(p.num_blocks,
                                                   cfg.subblocks)), rep,
                    calm0, cfg.i2)
        if warm.values.shape[0] != self._values_len:
            raise ValueError("warm values must be permuted + padded "
                             f"({warm.values.shape[0]} != {self._values_len})")
        rep = RepartitionState.warm(
            warm.is_hot, interval=cfg.repartition_interval,
            growth=cfg.repartition_growth)
        if cfg.adaptive and warm.calm is not None:
            calm0 = self._sub2d(warm.calm).astype(np.int32)
        i2 = (warm.i2 if cfg.adaptive and warm.i2 is not None
              else cfg.i2)
        psd0 = self._sub2d(np.asarray(warm.psd, dtype=np.float32))
        return (jnp.asarray(np.asarray(warm.values, dtype=np.float32)),
                jnp.asarray(psd0.astype(np.float32)), rep,
                calm0, int(i2))

    def _run_fused(self, max_iterations: int | None = None,
                   warm: WarmStart | None = None,
                   trace: bool = False) -> RunResult:
        cfg, p = self.config, self.plan
        max_it = max_iterations or cfg.max_iterations

        values, psd, rep, calm_host, i2 = self._start_state(warm)
        calm = jnp.asarray(calm_host)
        # host-side decisions (repartition, dispatch bucket, history) are
        # block-granular: fold the (P, S) sub-block psd to block priority
        psd_sub_host = np.asarray(psd)
        psd_host = state_lib.fold_subblock_psd(psd_sub_host)
        active = self._active_count(calm_host)
        dmax = jnp.zeros((p.num_blocks, cfg.subblocks), jnp.float32)
        acct = self._acct_table()
        metrics = Metrics()
        history = []
        depth_hist: dict[int, int] = {}
        width_iters = 0
        sb_total = 0
        # tracing: spans/counters go to the installed recorder (if any);
        # the device timeline needs only the traced chunk variant. The
        # acct table rides as a TRACED int32 arg so the device can expand
        # per-superstep schedule picks into counter deltas itself.
        rec = obs_trace.current() if trace else None
        timeline: list | None = [] if trace else None
        acct_dev = jnp.asarray(acct.astype(np.int32)) if trace else None
        # out-of-core paging: the host scheduler twin (decision-identical
        # to the fused device select, property-tested) predicts each
        # superstep's block demand so it can be paged in BEFORE the sweep
        # reads it — residency never changes the schedule, which is what
        # makes a budget-constrained run bitwise-identical to the fully
        # resident one. Paged chunks run one superstep at a time (the
        # demand set changes per superstep); the dispatch bucket is still
        # retargeted only at repartition boundaries, exactly the resident
        # cadence, so the trajectory cannot diverge.
        spill = self.spill
        pred = None
        if spill is not None:
            from repro.ooc import prefetch as ooc_policy
            spill.begin_run()
            pred = schedule_predictor(self._ladder[0], i2, cfg.cold_frac,
                                      self._psd_floor())
        wb = self._pick_width(active, psd_host)

        with Timer() as t:
            it = 0
            while it < max_it:
                if spill is None:
                    it_end = rep.chunk_end(max_it)
                else:
                    pred.width = wb
                    sel = pred.select(it, psd_sub_host, rep.is_hot)
                    spill.admit(ooc_policy.demand_blocks(sel, self.pad_id),
                                psd_host, calm_host)
                    it_end = it + 1
                # the device counts schedules per block (exact chunk-sized
                # int32s, zeroed each chunk); the host expands them through
                # the int64 accounting table at the boundary. The chunk
                # span (dispatch -> the boundary sync that realizes the
                # async device work) is the trace's wall window for the
                # chunk's supersteps.
                with obs_trace.span("chunk", cat="engine", it0=it,
                                    width=wb) as csp:
                    if trace:
                        cap = _hist_cap(it_end - it)
                        chunk = self._get_chunk(wb, cap)
                        (it_dev, values, psd, dmax, calm, counts, hslots,
                         sbacc, hist_i, hist_f, conv) = chunk(
                            self._ed, self._coupling_dev, values, psd,
                            dmax, calm,
                            jnp.zeros(p.num_blocks, jnp.int32),
                            jnp.zeros(wb, jnp.int32), jnp.int32(0),
                            jnp.int32(it), jnp.int32(it_end),
                            jnp.asarray(rep.is_hot), jnp.int32(i2),
                            acct_dev,
                            jnp.zeros((cap, len(TIMELINE_INT_COLS)),
                                      jnp.int32),
                            jnp.zeros((cap, len(TIMELINE_FLOAT_COLS)),
                                      jnp.float32))
                    else:
                        chunk = self._get_chunk(wb)
                        (it_dev, values, psd, dmax, calm, counts, hslots,
                         sbacc, conv) = chunk(
                            self._ed, self._coupling_dev, values, psd,
                            dmax, calm,
                            jnp.zeros(p.num_blocks, jnp.int32),
                            jnp.zeros(wb, jnp.int32), jnp.int32(0),
                            jnp.int32(it), jnp.int32(it_end),
                            jnp.asarray(rep.is_hot), jnp.int32(i2))
                    # the chunk's single host sync point
                    it_new = int(it_dev)
                    psd_sub_host = np.asarray(psd)
                    psd_host = state_lib.fold_subblock_psd(psd_sub_host)
                    calm_host = np.asarray(calm)
                    counts_host = np.asarray(counts, dtype=np.int64)
                    if trace:
                        # history buffers flush in the SAME sync — the
                        # per-superstep resolution is free of extra host
                        # round-trips
                        hi = np.asarray(hist_i)[:it_new - it]
                        hf = np.asarray(hist_f)[:it_new - it]
                        rows = []
                        for k in range(it_new - it):
                            row = {"superstep": it + k, "width": wb}
                            row.update(zip(TIMELINE_INT_COLS,
                                           (int(v) for v in hi[k])))
                            row.update(zip(TIMELINE_FLOAT_COLS,
                                           (float(v) for v in hf[k])))
                            rows.append(row)
                        timeline.extend(rows)
                    csp.set(it_end=it_new)
                if rec is not None and trace and rows:
                    rec.counter_rows("superstep", rows, csp.t0, csp.t1)
                delta = counts_host @ acct
                metrics.absorb_counters(delta)
                sb_total += int(sbacc)
                span = it_new - it
                width_iters += wb * span
                for d, cnt in zip(self._inner_depths(wb).tolist(),
                                  np.asarray(hslots).tolist()):
                    if cnt:
                        depth_hist[int(d)] = depth_hist.get(int(d), 0) + \
                            int(cnt)
                history.append({
                    "iteration": max(it_new - 1, 0),
                    "span": span,  # iterations covered by this entry
                    "psd_sum": float(psd_host[psd_host <
                                              state_lib.UNSEEN].sum()),
                    "unseen": int((psd_host >= state_lib.UNSEEN).sum()),
                    "hot_blocks": int(rep.is_hot.sum()),
                    "scheduled": int(delta[2]),  # block loads
                    "width": wb,
                    "retired": p.num_blocks - self._active_count(calm_host),
                })
                if bool(conv):
                    metrics.converged = True
                    it = it_new
                    break
                if it_new == it:  # schedule went empty: nothing left to do
                    break
                it = it_new
                # a no-op until it - 1 reaches the boundary, so the paged
                # per-superstep calls fire on exactly the resident cadence
                with obs_trace.span("repartition", cat="engine",
                                    iteration=it - 1) as rsp:
                    fired = rep.maybe_repartition(it - 1, psd_host,
                                                  cfg.hot_ratio)
                    rsp.set(fired=fired)
                # next chunk's bucket follows the live active set, exactly
                # like the host loop's boundary retarget. In paged mode the
                # bucket changes ONLY at fired boundaries (the resident
                # path's chunks always end at boundaries, so this is the
                # same retarget cadence — a per-superstep retarget would
                # change the cold quota and fork the trajectory).
                active = self._active_count(calm_host)
                if spill is None or fired:
                    wb = self._pick_width(active, psd_host)
                if spill is not None and fired:
                    # activity-directed prefetch at the boundary: stage the
                    # predicted next-superstep demand plus the hottest
                    # non-resident blocks, swapping out retired ones only
                    pred.width = wb
                    nsel = pred.select(it, psd_sub_host, rep.is_hot)
                    spill.prefetch_boundary(
                        ooc_policy.demand_blocks(nsel, self.pad_id),
                        psd_host, calm_host)
        metrics.iterations = it
        metrics.wall_time_s = t.elapsed
        metrics.mean_dispatch_width = width_iters / max(it, 1)
        metrics.blocks_retired = p.num_blocks - self._active_count(calm_host)
        metrics.inner_depth_hist = depth_hist
        metrics.subblocks_retired = self._subblocks_retired(calm_host)
        metrics.mean_subblock_dispatch = sb_total / \
            max(metrics.block_loads, 1)
        if spill is not None:
            spill.flush_metrics(metrics)
        self.last_psd = psd_sub_host
        self.last_calm = np.asarray(calm_host)
        out = np.asarray(values)[self.plan.inv]  # back to original ids
        return RunResult(values=out, metrics=metrics, history=history,
                         timeline=timeline)

    def _run_host(self, max_iterations: int | None = None,
                  warm: WarmStart | None = None,
                  trace: bool = False) -> RunResult:
        cfg, p = self.config, self.plan
        max_it = max_iterations or cfg.max_iterations

        values, psd, rep, calm_host, i2 = self._start_state(warm)
        # psd_sub is the raw (P, S) sub-block state (sb-dispatch accounting
        # + the scheduler folds it internally); psd_host its block fold for
        # the host-side block-granular decisions
        psd_sub = np.asarray(psd)
        psd_host = state_lib.fold_subblock_psd(psd_sub)
        sched = Scheduler(width=self._pick_width(
                              self._active_count(calm_host), psd_host),
                          i2=i2, cold_frac=cfg.cold_frac,
                          min_psd=self._psd_floor())
        calm = jnp.asarray(calm_host)
        dmax = jnp.zeros((p.num_blocks, cfg.subblocks), jnp.float32)
        floor = self._psd_floor()
        metrics = Metrics()
        history = []
        depth_hist: dict[int, int] = {}
        hslots = np.zeros(cfg.width, dtype=np.int64)
        width_iters = 0
        sb_total = 0
        # host-path timeline: computed per iteration from the same acct
        # table and post-superstep state the fused history buffers record
        timeline: list | None = [] if trace else None
        acct = self._acct_table() if trace else None
        spill = self.spill
        if spill is not None:
            from repro.ooc import prefetch as ooc_policy
            spill.begin_run()

        with Timer() as t:
            it = 0
            while it < max_it:
                sel: Selection = sched.select(it, psd_sub, rep.is_hot)
                if sel.hot_ids.size == 0 and sel.cold_ids.size == 0:
                    break
                if spill is not None:
                    # page the selected slate in before dispatch touches it
                    # (block 0 — the host dispatch's row padding — is
                    # pinned resident by the store)
                    spill.admit(ooc_policy.demand_blocks(sel, self.pad_id),
                                psd_host, np.asarray(calm))
                processed = np.concatenate([sel.hot_ids, sel.cold_ids])
                w_used = sched.width  # this iteration's bucket (the
                # boundary retarget below may change it before history)
                # live sub-blocks actually swept this iteration, from the
                # same pre-sweep psd the device masks derive from
                sb_total += int((psd_sub[processed] >= floor).sum())
                values, psd, dmax = self._dispatch(
                    values, psd, dmax, sel.hot_ids, sequential=True,
                    width=sched.width)
                values, psd, dmax = self._dispatch(
                    values, psd, dmax, sel.cold_ids, sequential=False,
                    width=sched.width)
                self._account(metrics, processed)
                hslots[:sel.hot_ids.size] += 1
                width_iters += sched.width
                # staleness propagation (device-side max-product matvec):
                # a max per-vertex delta v in block j can move block b's
                # mean-PSD by at most decay * v * coupling(j->b); the post
                # also advances the calm/retire counters.
                psd, dmax, calm = self._post(self._coupling_dev, psd, dmax,
                                             calm)
                psd_sub = np.asarray(psd)
                psd_host = state_lib.fold_subblock_psd(psd_sub)
                with obs_trace.span("repartition", cat="engine",
                                    iteration=it) as rsp:
                    fired = rep.maybe_repartition(it, psd_host,
                                                  cfg.hot_ratio)
                    rsp.set(fired=fired)
                if fired and cfg.adaptive:
                    # boundary retarget: same cadence as the fused path's
                    # per-chunk bucket pick
                    calm_host = np.asarray(calm)
                    sched.width = self._pick_width(
                        self._active_count(calm_host), psd_host)
                if fired and spill is not None:
                    # boundary prefetch: stage the predicted next-iteration
                    # demand + the hottest non-resident blocks
                    nsel = sched.select(it + 1, psd_sub, rep.is_hot)
                    spill.prefetch_boundary(
                        ooc_policy.demand_blocks(nsel, self.pad_id),
                        psd_host, np.asarray(calm))
                history.append({
                    "iteration": it,
                    "psd_sum": float(psd_host[psd_host <
                                              state_lib.UNSEEN].sum()),
                    "unseen": int((psd_host >= state_lib.UNSEEN).sum()),
                    "hot_blocks": int(rep.is_hot.sum()),
                    "scheduled": int(processed.size),
                    "width": sched.width,
                })
                if trace:
                    # same columns/definitions as the fused history
                    # buffers: counter deltas via the acct table, retired/
                    # PSD stats from the post-superstep state
                    d = acct[processed].sum(axis=0) if processed.size \
                        else np.zeros(4, dtype=np.int64)
                    finite = psd_host < state_lib.UNSEEN
                    row = {"superstep": it, "width": w_used,
                           "hot_loads": int(sel.hot_ids.size),
                           "retired": p.num_blocks
                           - self._active_count(np.asarray(calm)),
                           "unseen": int((~finite).sum()),
                           "psd_sum": float(
                               psd_host[finite].astype(np.float32).sum()),
                           "psd_max": float(
                               psd_host[finite].max()) if finite.any()
                           else 0.0}
                    row.update(zip(COUNTER_FIELDS,
                                   (int(v) for v in d)))
                    timeline.append(row)
                it += 1
                if state_lib.converged(psd_sub, cfg.t2):
                    metrics.converged = True
                    break
        calm_host = np.asarray(calm)
        depths = self._inner_depths(cfg.width)
        for d, cnt in zip(depths.tolist(), hslots.tolist()):
            if cnt:
                depth_hist[int(d)] = depth_hist.get(int(d), 0) + int(cnt)
        metrics.iterations = it
        metrics.wall_time_s = t.elapsed
        metrics.mean_dispatch_width = width_iters / max(it, 1)
        metrics.blocks_retired = p.num_blocks - self._active_count(calm_host)
        metrics.inner_depth_hist = depth_hist
        metrics.subblocks_retired = self._subblocks_retired(calm_host)
        metrics.mean_subblock_dispatch = sb_total / \
            max(metrics.block_loads, 1)
        if spill is not None:
            spill.flush_metrics(metrics)
        self.last_psd = psd_sub
        self.last_calm = calm_host
        out = np.asarray(values)[self.plan.inv]  # back to original ids
        return RunResult(values=out, metrics=metrics, history=history,
                         timeline=timeline)


def coupling_from_counts(block_edge_counts: np.ndarray,
                         program: VertexProgram,
                         block_size: int) -> np.ndarray:
    """(P, P) staleness-coupling matrix from the block->block edge-count
    matrix W_jb (number of edges from block j's vertices into block b).
    Factored out of the engine so the streaming subsystem can maintain W
    incrementally under edge deltas and refresh K without an O(m) rescan.
    """
    w = block_edge_counts
    if program.combine == "sum":
        k = (np.minimum(w, block_size) / block_size).astype(np.float32)
        return k * np.float32(program.damping)
    return (w > 0).astype(np.float32)


# -- Betweenness centrality (Brandes, sampled sources) -----------------------
def betweenness(graph: Graph, sources: list[int],
                config: EngineConfig = EngineConfig(),
                structure_aware: bool = True) -> tuple[np.ndarray, Metrics]:
    """BC per paper's algorithm set: the forward BFS waves run through the
    structure-aware engine (or the baseline when structure_aware=False); the
    path-counting and dependency accumulation are level-synchronous dense
    sweeps (they are single passes, not iterative-convergent phases)."""
    from repro.core import algorithms as algos
    from repro.core.baseline import BaselineEngine

    n = graph.n
    bc = np.zeros(n, dtype=np.float64)
    total = Metrics()
    s_arr, d_arr, _ = _coo(graph)
    for s in sources:
        prog = algos.bfs(source=s)
        eng = (StructureAwareEngine(graph, prog, config) if structure_aware
               else BaselineEngine(graph, prog, config))
        res = eng.run()
        dist = res.values
        for k, v in res.metrics.as_dict().items():
            # skip non-summable entries: converged, and derived rates that
            # as_dict computes from counters (read-only properties)
            if (isinstance(v, (int, float)) and k != "converged"
                    and not isinstance(getattr(type(total), k, None),
                                       property)):
                setattr(total, k, getattr(total, k) + v)
        # sigma: #shortest paths, level-synchronous accumulation
        finite = dist < algos.INF / 2
        max_lvl = int(dist[finite].max()) if finite.any() else 0
        sigma = np.zeros(n, dtype=np.float64)
        sigma[s] = 1.0
        on_sp = dist[d_arr] == dist[s_arr] + 1
        for lvl in range(1, max_lvl + 1):
            e = on_sp & (dist[d_arr] == lvl)
            np.add.at(sigma, d_arr[e], sigma[s_arr[e]])
        # delta: backward dependency accumulation
        delta = np.zeros(n, dtype=np.float64)
        for lvl in range(max_lvl, 0, -1):
            e = on_sp & (dist[d_arr] == lvl)
            contrib = sigma[s_arr[e]] / np.maximum(sigma[d_arr[e]], 1.0) * \
                (1.0 + delta[d_arr[e]])
            np.add.at(delta, s_arr[e], contrib)
        delta[s] = 0.0
        bc += delta
    return bc, total


def _coo(g: Graph):
    dst = np.repeat(np.arange(g.n, dtype=np.int64), g.in_deg)
    return g.in_src.astype(np.int64), dst, g.in_w
