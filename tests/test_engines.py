"""Engine correctness: both engines reach the same fixpoint as numpy
oracles, on every algorithm, across graph families (the paper's exactness
requirement — scheduling must never change results)."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from conftest import bellman_ford_oracle, cc_oracle, pr_oracle
from repro.core import algorithms as A
from repro.core import graph as G
from repro.core.baseline import BaselineEngine
from repro.core.engine import EngineConfig, StructureAwareEngine, betweenness
from repro.core.repartition import RepartitionState
from repro.core.schedule import Scheduler, make_device_select
from repro.core import state as state_lib

CFG = EngineConfig(t2=1e-9, width=8, block_size=256)


def _close(a, b, **kw):
    return np.allclose(np.minimum(a, 1e18), np.minimum(b, 1e18), **kw)


@pytest.mark.parametrize("gname", ["powerlaw", "core_periphery", "uniform"])
def test_pagerank_matches_oracle(gname):
    g = {"powerlaw": G.powerlaw_graph(2000, 6, seed=2),
         "core_periphery": G.core_periphery_graph(3000, 6, seed=2, chords=1),
         "uniform": G.uniform_graph(1500, 4, seed=2)}[gname]
    oracle = pr_oracle(g)
    res = StructureAwareEngine(g, A.pagerank(), CFG).run()
    assert res.metrics.converged
    assert _close(res.values, oracle, rtol=1e-3, atol=1e-6)


@pytest.mark.parametrize("prog_name", ["sssp", "bfs"])
def test_traversal_matches_oracle(prog_name, powerlaw_small):
    g = G.powerlaw_graph(2000, 6, seed=3, weighted=(prog_name == "sssp"))
    prog = A.sssp(0) if prog_name == "sssp" else A.bfs(0)
    oracle = bellman_ford_oracle(g, 0, unit=(prog_name == "bfs"))
    res = StructureAwareEngine(g, prog, CFG).run()
    assert res.metrics.converged
    assert _close(res.values, oracle.astype(np.float32), rtol=1e-5,
                  atol=1e-3)


def test_cc_matches_union_find():
    g = G.powerlaw_graph(1000, 3, seed=4)
    res = StructureAwareEngine(g, A.cc(), CFG).run()
    roots = cc_oracle(G.symmetrize(g))
    # same component <=> same propagated max label
    for r in np.unique(roots):
        labels = res.values[roots == r]
        assert len(np.unique(labels)) == 1


@given(n=st.integers(100, 800), avg=st.integers(2, 6),
       seed=st.integers(0, 20),
       algo=st.sampled_from(["pagerank", "sssp", "bfs", "cc"]))
@settings(max_examples=10, deadline=None)
def test_engines_agree_property(n, avg, seed, algo):
    """Property: structure-aware scheduling NEVER changes the fixpoint."""
    g = G.powerlaw_graph(n, avg_deg=avg, seed=seed, weighted=True)
    prog = {"pagerank": A.pagerank, "cc": A.cc,
            "sssp": lambda: A.sssp(0), "bfs": lambda: A.bfs(0)}[algo]()
    cfg = EngineConfig(t2=1e-9, width=4, block_size=128)
    base = BaselineEngine(g, prog, cfg).run()
    sa = StructureAwareEngine(g, prog, cfg).run()
    assert _close(base.values, sa.values, rtol=1e-3, atol=1e-5)


def test_structure_aware_wins_on_skewed_graph():
    """The paper's claim: fewer updates + partition loads than the dense
    baseline on convergence-skewed graphs (>= 2x, the paper reports ~2x)."""
    g = G.core_periphery_graph(20000, avg_deg=8, seed=1, chords=1)
    cfg = EngineConfig(t2=1e-9, width=16, block_size=512)
    base = BaselineEngine(g, A.pagerank(), cfg, frontier=False).run()
    sa = StructureAwareEngine(g, A.pagerank(), cfg).run()
    assert _close(base.values, sa.values, rtol=1e-3, atol=1e-6)
    assert base.metrics.updates / sa.metrics.updates >= 2.0
    assert base.metrics.block_loads / sa.metrics.block_loads >= 2.0


def test_betweenness_engines_agree():
    g = G.powerlaw_graph(500, 4, seed=5)
    bc_sa, _ = betweenness(g, [0, 3], CFG, structure_aware=True)
    bc_base, _ = betweenness(g, [0, 3], CFG, structure_aware=False)
    assert np.allclose(bc_sa, bc_base, rtol=1e-4, atol=1e-6)


def test_betweenness_chain_oracle():
    """Directed path 0->1->...->k from source 0: Brandes dependency is
    delta(v) = (n-1) - v, and the source itself accumulates nothing."""
    n = 8
    g = G.chain_graph(n)
    bc, metrics = betweenness(g, [0], CFG)
    expect = np.array([0.0] + [n - 1 - v for v in range(1, n)])
    assert np.allclose(bc, expect, atol=1e-6)
    assert metrics.iterations > 0 and metrics.updates > 0


def test_betweenness_diamond_split_paths():
    """Two equal-length shortest paths: the middles share the dependency
    (sigma-weighted), the endpoints carry none."""
    #    0 -> 1 -> 3 ; 0 -> 2 -> 3
    g = G.from_edges(4, [0, 0, 1, 2], [1, 2, 3, 3])
    bc, _ = betweenness(g, [0], CFG)
    assert np.allclose(bc, [0.0, 0.5, 0.5, 0.0], atol=1e-6)


def test_dead_partition_one_shot():
    """Zero-degree vertices converge at init and are never scheduled."""
    g = G.from_edges(10, [0, 1], [1, 0])  # vertices 2..9 dead
    eng = StructureAwareEngine(g, A.pagerank(), CFG)
    assert eng.plan.n_dead == 8
    res = eng.run()
    # dead PR value = (1-d)/n exactly
    assert np.allclose(res.values[2:], 0.15 / 10, atol=1e-7)


# -- fused superstep loop ----------------------------------------------------
@given(n=st.integers(100, 800), avg=st.integers(2, 6),
       seed=st.integers(0, 20),
       algo=st.sampled_from(["pagerank", "sssp", "bfs", "cc"]),
       adaptive=st.booleans())
@settings(max_examples=10, deadline=None)
def test_fused_matches_host_loop_property(n, avg, seed, algo, adaptive):
    """Property: the device-resident lax.while_loop engine reaches the SAME
    fixpoint as the host-driven reference loop — values, iteration count,
    and metric accounting — for every program class (sum + min/max, i.e.
    barrier + universal repartitioning with the cold re-heat path), with
    the adaptive active-set model ON as well as on the dense fallback
    (decision parity of retirement, depth ladder, and width buckets)."""
    g = G.powerlaw_graph(n, avg_deg=avg, seed=seed, weighted=True)
    prog = {"pagerank": A.pagerank, "cc": A.cc,
            "sssp": lambda: A.sssp(0), "bfs": lambda: A.bfs(0)}[algo]()
    cfg = EngineConfig(t2=1e-9, width=4, block_size=128, adaptive=adaptive)
    host = StructureAwareEngine(g, prog, cfg).run(fused=False)
    fused = StructureAwareEngine(g, prog, cfg).run(fused=True)
    assert _close(host.values, fused.values, rtol=1e-5, atol=1e-6)
    assert abs(host.metrics.iterations - fused.metrics.iterations) <= 1
    assert host.metrics.converged == fused.metrics.converged
    assert host.metrics.updates == fused.metrics.updates
    assert host.metrics.block_loads == fused.metrics.block_loads
    assert host.metrics.bytes_loaded == fused.metrics.bytes_loaded


@given(n=st.integers(200, 800), seed=st.integers(0, 20),
       algo=st.sampled_from(["pagerank", "sssp", "cc"]))
@settings(max_examples=8, deadline=None)
def test_adaptive_dense_host_fixpoint_property(n, seed, algo):
    """Property (adaptive tentpole): the adaptive fused path, the dense
    fused path, and the host reference loop all declare convergence at
    SUM(psd) < t2 and land on the same fixpoint — the adaptive schedule
    (retirement, depth ladder, width buckets) changes effort, never
    results."""
    g = G.powerlaw_graph(n, avg_deg=4, seed=seed, weighted=True)
    prog = {"pagerank": A.pagerank, "cc": A.cc,
            "sssp": lambda: A.sssp(0)}[algo]
    cfg = EngineConfig(t2=1e-9, width=4, block_size=128,
                       retire_after=2)
    ra = StructureAwareEngine(g, prog(), cfg).run(fused=True)
    rd = StructureAwareEngine(
        g, prog(), dataclasses.replace(cfg, adaptive=False)).run(fused=True)
    rh = StructureAwareEngine(g, prog(), cfg).run(fused=False)
    assert ra.metrics.converged and rd.metrics.converged \
        and rh.metrics.converged
    assert _close(ra.values, rd.values, rtol=1e-4, atol=1e-5)
    assert _close(ra.values, rh.values, rtol=1e-5, atol=1e-6)
    # the dense fallback reports no adaptive activity
    assert rd.metrics.blocks_retired == 0
    assert rd.metrics.mean_dispatch_width == cfg.width
    assert list(rd.metrics.inner_depth_hist) in ([], [cfg.hot_inner_iters])


# -- adaptive active set: retirement, depth ladder, width buckets ------------
def test_block_retire_and_rearm():
    """A block whose PSD stays under the pruning floor for ``retire_after``
    consecutive supersteps is retired from the active set (narrowest
    dispatch bucket, nothing schedulable); a staleness-coupling bump lifts
    its downstream blocks back over the floor, resets their calm counters,
    and they are dispatched again."""
    g = G.chain_graph(512, weighted=True)
    cfg = EngineConfig(t2=1e-6, width=4, block_size=128, retire_after=2)
    eng = StructureAwareEngine(g, A.pagerank(), cfg)
    p = eng.plan.num_blocks
    floor = eng._psd_floor()
    psd = jnp.zeros(p, jnp.float32)
    dmax = jnp.zeros(p, jnp.float32)
    calm = jnp.zeros(p, jnp.int32)
    # quiescent supersteps: every block retires after retire_after posts
    for _ in range(cfg.retire_after):
        psd, dmax, calm = eng._post(eng._coupling_dev, psd, dmax, calm)
    calm_h = np.asarray(calm)
    assert (calm_h >= cfg.retire_after).all()
    assert eng._active_count(calm_h) == 0
    assert eng._pick_width(0, np.asarray(psd)) == cfg.min_width
    sched = Scheduler(width=cfg.width, i2=cfg.i2, min_psd=floor)
    sel = sched.select(0, np.asarray(psd), np.zeros(p, dtype=bool))
    assert sel.hot_ids.size == 0 and sel.cold_ids.size == 0  # retired
    # a delta in block 0 re-arms its downstream blocks through the
    # coupling: calm resets and the scheduler dispatches them again
    dmax = jnp.zeros(p, jnp.float32).at[0].set(1.0)
    psd, dmax, calm = eng._post(eng._coupling_dev, psd, dmax, calm)
    psd_h, calm_h = np.asarray(psd), np.asarray(calm)
    rearmed = np.flatnonzero(psd_h >= floor)
    assert rearmed.size > 0
    assert (calm_h[rearmed] == 0).all()
    assert eng._active_count(calm_h) == rearmed.size
    sel = sched.select(0, psd_h, np.zeros(p, dtype=bool))
    assert sel.cold_ids.size == min(cfg.width, rearmed.size)
    assert set(sel.cold_ids.tolist()) <= set(rearmed.tolist())


def test_width_ladder_pick_and_adaptive_i2():
    from repro.core.schedule import adaptive_i2, pick_width, width_ladder
    assert width_ladder(16, 2) == [16, 8, 4, 2]
    assert width_ladder(12, 2) == [12, 8, 4, 2]
    assert width_ladder(16, 4) == [16, 8, 4]
    assert width_ladder(1, 2) == [1]
    lad = width_ladder(16, 2)
    assert pick_width(lad, 0) == 2
    assert pick_width(lad, 2) == 2
    assert pick_width(lad, 3) == 4
    assert pick_width(lad, 9) == 16
    assert pick_width(lad, 100) == 16  # never wider than configured
    assert adaptive_i2(4, 40, 40) == 4  # dense perturbation: base cadence
    assert adaptive_i2(4, 40, 10) == 4  # a quarter of the blocks: base
    assert adaptive_i2(4, 40, 5) == 8  # 1/8 perturbed: 2x rarer admission
    assert adaptive_i2(4, 40, 1) == 32  # tiny batch: capped at 8x
    assert adaptive_i2(0, 40, 1) == 0  # disabled cadence stays disabled


def test_inner_depth_ladder():
    g = G.powerlaw_graph(300, 4, seed=0)
    cfg = EngineConfig(width=8, hot_inner_iters=8)
    eng = StructureAwareEngine(g, A.pagerank(), cfg)
    assert eng._inner_depths(8).tolist() == [8, 4, 2, 1, 1, 1, 1, 1]
    dense = StructureAwareEngine(
        g, A.pagerank(), dataclasses.replace(cfg, adaptive=False))
    assert dense._inner_depths(8).tolist() == [8] * 8


def test_fused_reheat_path():
    """Universal mode on a traversal program: cold blocks must re-heat when
    the wavefront reaches them after their PSD decayed, across several
    repartition boundaries, and the fused loop must agree with the
    reference loop through all of them."""
    g = G.uniform_graph(3000, deg=4, seed=9, weighted=True)
    cfg = EngineConfig(t2=1e-9, width=4, block_size=128,
                       repartition_interval=2, repartition_growth=1.2)
    host = StructureAwareEngine(g, A.sssp(0), cfg).run(fused=False)
    fused = StructureAwareEngine(g, A.sssp(0), cfg).run(fused=True)
    assert fused.metrics.converged and host.metrics.converged
    assert _close(host.values, fused.values, rtol=1e-5, atol=1e-6)
    assert len(fused.history) > 2  # several host consultations happened
    oracle = bellman_ford_oracle(g, 0)
    assert _close(fused.values, oracle.astype(np.float32), rtol=1e-5,
                  atol=1e-3)


def test_fused_host_sync_cadence():
    """Host transfers are O(iterations / repartition_interval): one history
    entry per repartition boundary, each covering a whole chunk."""
    g = G.powerlaw_graph(2000, 6, seed=2)
    res = StructureAwareEngine(g, A.pagerank(), CFG).run(fused=True)
    spans = [h["span"] for h in res.history]
    assert sum(spans) == res.metrics.iterations
    assert len(res.history) < res.metrics.iterations  # chunked, not per-iter
    assert max(spans) > 1


@given(p=st.integers(2, 40), width=st.integers(1, 12),
       i2=st.integers(0, 5), it=st.integers(0, 9), seed=st.integers(0, 50))
@settings(max_examples=25, deadline=None)
def test_device_select_matches_numpy(p, width, i2, it, seed):
    """The jnp scheduler is decision-identical to the numpy reference:
    same blocks, same order, same tie-breaking."""
    rng = np.random.default_rng(seed)
    psd = rng.choice([0.0, 1e-13, 0.5, 0.5, 1.0, 2.0, state_lib.UNSEEN],
                     size=p).astype(np.float32)
    is_hot = rng.random(p) < 0.4
    sched = Scheduler(width=width, i2=i2, cold_frac=0.25, min_psd=1e-12)
    sel = sched.select(it, psd, is_hot)
    dev = make_device_select(width=width, cold_frac=0.25, min_psd=1e-12)
    hot_rows, hot_ok, cold_rows, cold_ok = (np.asarray(x) for x in
                                            dev(it, i2, psd, is_hot))
    assert np.array_equal(hot_rows[hot_ok], sel.hot_ids)
    assert np.array_equal(cold_rows[cold_ok], sel.cold_ids)


# -- Pallas combine path ------------------------------------------------------
@given(n=st.integers(200, 600), avg=st.integers(3, 6),
       seed=st.integers(0, 10))
@settings(max_examples=4, deadline=None)
def test_pallas_combine_matches_dense_property(n, avg, seed):
    """Property: the ``use_pallas=True`` sum-combine (the spmv one-hot
    matmul kernel, interpreted on CPU) runs the IDENTICAL trajectory to
    the dense scatter-add combine — values and every metric counter —
    end-to-end through the fused engine, not just at the kernel level."""
    g = G.powerlaw_graph(n, avg_deg=avg, seed=seed, weighted=True)
    cfg = EngineConfig(t2=1e-9, width=4, block_size=128)
    dense = StructureAwareEngine(g, A.pagerank(), cfg).run()
    pal = StructureAwareEngine(
        g, A.pagerank(), dataclasses.replace(cfg, use_pallas=True)).run()
    assert pal.metrics.converged and dense.metrics.converged
    assert _close(dense.values, pal.values, rtol=1e-6, atol=1e-7)
    for f in ("iterations", "updates", "edges_processed", "block_loads",
              "bytes_loaded"):
        assert getattr(dense.metrics, f) == getattr(pal.metrics, f), f


# -- scheduler / repartition units -------------------------------------------
def test_scheduler_i2_cadence():
    psd = np.array([5.0, 4.0, 3.0, 2.0, 1.0], np.float32)
    is_hot = np.array([True, True, False, False, False])
    s = Scheduler(width=2, i2=4, cold_frac=0.5)
    sel0 = s.select(0, psd, is_hot)  # I2 round: 1 hot + 1 cold
    assert list(sel0.hot_ids) == [0] and list(sel0.cold_ids) == [2]
    sel1 = s.select(1, psd, is_hot)  # hot-only round
    assert list(sel1.hot_ids) == [0, 1] and sel1.cold_ids.size == 0


def test_scheduler_work_conserving_topup():
    psd = np.array([5.0, 3.0, 2.0, 1.0], np.float32)
    is_hot = np.array([True, False, False, False])
    s = Scheduler(width=3, i2=0)
    sel = s.select(1, psd, is_hot)
    assert list(sel.hot_ids) == [0]
    assert list(sel.cold_ids) == [1, 2]  # idle workers take top cold


def test_scheduler_prunes_converged():
    psd = np.array([1e-13, 1e-13, 1e-13], np.float32)
    is_hot = np.array([True, False, False])
    s = Scheduler(width=2, min_psd=1e-12)
    sel = s.select(0, psd, is_hot)
    assert sel.hot_ids.size == 0 and sel.cold_ids.size == 0


def test_barrier_monotone():
    rep = RepartitionState.create(6, 4, "barrier", interval=1)
    psd = np.array([1.0, 1.0, 1e-9, 1e-9, 0.5, 0.5], np.float32)
    rep.maybe_repartition(1, psd, hot_ratio=0.5)
    assert rep.barrier == 2  # trailing quiesced hot blocks cooled
    assert rep.is_hot[:2].all() and not rep.is_hot[2:].any()
    b = rep.barrier
    # barrier never moves backwards even if PSD re-rises
    psd[:] = 10.0
    rep.maybe_repartition(10, psd, hot_ratio=0.5)
    assert rep.barrier <= b


def test_universal_reheats():
    rep = RepartitionState.create(4, 2, "universal", interval=1)
    psd = np.array([1e-9, 1e-9, 5.0, 6.0], np.float32)
    rep.maybe_repartition(1, psd, hot_ratio=0.5)
    assert not rep.is_hot[0] and not rep.is_hot[1]
    assert rep.is_hot[2] and rep.is_hot[3]  # cold blocks re-heated


def test_convergence_unseen_sentinel():
    psd = state_lib.init_psd(3)
    assert not state_lib.converged(psd, 1e-6)
    psd[:] = 1e-8
    assert state_lib.converged(psd, 1e-6)
