"""SpillStore: per-block residency over the unified tiled layout.

Device memory is modeled as a fixed budget of resident block slots
(``EngineConfig.resident_blocks``). A non-resident block's edge tile rows
are *really* gone from the device — eviction scatters invalidated rows
over them through the engine's donated row-scatter path — and live in a
host payload cache and/or per-block npz disk segments (written by an
async single-writer thread in the style of ``repro.ckpt.manager``). The
engine demand-fetches every block its predicted schedule needs *before*
entering the superstep, so the schedule itself never changes: a
budget-constrained run is bitwise-identical (values and algorithmic
counters) to the fully resident one — the property the OOC tests pin.

What spills: the per-block EDGE tile rows (src / dst_local / w / valid —
the O(m) state). Vertex values, PSD/calm activity and aux stay resident:
the sweeps are pull-mode (any scheduled block gathers ``values[e_src]``
graph-wide), so value slices of unscheduled blocks are still read every
superstep, and the activity state is exactly what the prefetch policy
steers by. Those are O(n) and O(P*S); the edge tiles are the memory
story.

Payload source of truth, in priority order:

  1. ``row_source`` — a host-side truth oracle (the streaming engine
     wires ``MutableTiledState.rows2d`` here), always current under
     ingest mutation;
  2. the host payload cache captured at eviction time;
  3. the npz disk segment (``keep_host=False`` drops the cache once the
     segment is durable — the graphs-bigger-than-RAM tier).

``on_evict`` fires before the device rows are invalidated so the serve
layer can preserve pinned epoch snapshots (see
``StreamingEngine.snapshot``); ``materialize`` rebuilds a fully-resident
:class:`EdgeData` copy for such pins without changing residency.
"""
from __future__ import annotations

import os
import queue
import threading

import jax.numpy as jnp
import numpy as np

from repro.core.engine import EdgeData, tile_coverage
from repro.obs import trace as obs_trace
from repro.ooc import prefetch as policy


class _AsyncSegmentWriter:
    """Single daemon writer draining (block, payload) jobs to atomic npz
    segments — the ckpt-manager write discipline (tmp + rename) applied
    per block. ``wait`` drains the queue; readers call it before touching
    a segment that might still be in flight."""

    def __init__(self, directory: str):
        os.makedirs(directory, exist_ok=True)
        self.dir = directory
        self._q: queue.Queue = queue.Queue()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def path(self, block: int) -> str:
        return os.path.join(self.dir, f"blk_{block:06d}.npz")

    def submit(self, block: int, payload: dict) -> None:
        self._q.put((block, payload))

    def _loop(self) -> None:
        while True:
            block, payload = self._q.get()
            try:
                final = self.path(block)
                tmp = final + ".tmp.npz"
                np.savez(tmp, **payload)
                os.replace(tmp, final)  # atomic publish
            finally:
                self._q.task_done()

    def wait(self) -> None:
        self._q.join()


class SpillStore:
    """Residency tracker + spill tier for one engine epoch."""

    PAYLOAD_FIELDS = ("src", "dst_local", "w", "valid")

    def __init__(self, engine, budget: int, directory: str | None = None,
                 keep_host: bool | None = None):
        plan = engine.plan
        self.engine = engine
        self.num_blocks = int(plan.num_blocks)
        self.budget = int(budget)
        min_budget = int(engine.config.width) + 2  # schedule + pad + host pad
        if self.budget < min_budget:
            raise ValueError(
                f"resident_blocks={self.budget} cannot hold one dispatch: "
                f"need >= width + 2 = {min_budget} slots (the scheduled "
                "slate plus the pinned pad blocks)")
        self.resident = np.ones(self.num_blocks, dtype=bool)
        # the pad block fills every non-ok dispatch slot (the sweeps still
        # compute it) and the host loop pads its chunks with block 0 —
        # both must always be resident
        self.pinned = np.zeros(self.num_blocks, dtype=bool)
        self.pinned[[0, engine.pad_id]] = True
        self.floor = engine._psd_floor()
        self.retire_after = int(engine.config.retire_after)
        ts = plan.unified.tile_start.astype(np.int64)
        tc = plan.unified.tile_cnt.astype(np.int64)
        self._rows = [np.arange(ts[b], ts[b] + tc[b], dtype=np.int64)
                      for b in range(self.num_blocks)]
        self.row_source = None  # callable(rows) -> payload dict, or None
        self.on_evict = None  # pre-invalidation hook (epoch-pin preservation)
        self._cache: dict[int, dict] = {}
        self._writer = (_AsyncSegmentWriter(directory)
                        if directory is not None else None)
        self.keep_host = (self._writer is None if keep_host is None
                          else bool(keep_host))
        self._zero_counters()

    # -- accounting ----------------------------------------------------------
    def _zero_counters(self) -> None:
        self.spill_evictions = 0
        self.bytes_spilled = 0
        self.prefetch_hits = 0
        self.prefetch_misses = 0
        self.bytes_fetched = 0

    def begin_run(self) -> None:
        """Reset the per-run counters (residency itself persists across
        runs — the out-of-core steady state)."""
        self._zero_counters()

    def flush_metrics(self, metrics) -> None:
        metrics.spill_evictions += self.spill_evictions
        metrics.bytes_spilled += self.bytes_spilled
        metrics.prefetch_hits += self.prefetch_hits
        metrics.prefetch_misses += self.prefetch_misses
        metrics.bytes_fetched += self.bytes_fetched

    @property
    def spilled_blocks(self) -> np.ndarray:
        return np.flatnonzero(~self.resident)

    def block_rows(self, block: int) -> np.ndarray:
        return self._rows[block]

    def _payload_bytes(self, rows: int) -> int:
        # 4B src + 4B dst offset + 4B w + 1B valid per slot
        tile = int(self.engine.plan.unified.src.shape[1])
        return rows * tile * 13

    # -- payload plumbing ----------------------------------------------------
    def _gather_device(self, rows: np.ndarray) -> dict:
        """Read tile rows back off the device (engines without a host
        truth oracle capture the payload at eviction time)."""
        ed = self.engine.edge_state
        r = jnp.asarray(rows)
        return {"src": np.asarray(ed.src[r]),
                "dst_local": np.asarray(ed.dstl[r]),
                "w": np.asarray(ed.w[r]),
                "valid": np.asarray(ed.valid[r])}

    def _payload_of(self, block: int) -> dict:
        """Spilled block's tile rows, from truth > cache > disk segment."""
        if self.row_source is not None:
            return self.row_source(self._rows[block])
        payload = self._cache.get(block)
        if payload is not None:
            return payload
        if self._writer is None:
            raise KeyError(f"no spill payload for block {block}")
        self._writer.wait()  # the segment may still be in flight
        with np.load(self._writer.path(block)) as z:
            return {k: z[k] for k in self.PAYLOAD_FIELDS}

    # -- residency transitions ----------------------------------------------
    def evict(self, blocks: np.ndarray) -> None:
        """Move blocks' tile rows off-device: capture the payload, stage
        the disk segment (async), then invalidate the device rows through
        the engine's donated row scatter — the rows are really gone, not
        just masked in host bookkeeping."""
        blocks = np.asarray(blocks, dtype=np.int64)
        blocks = blocks[self.resident[blocks] & ~self.pinned[blocks]]
        if blocks.size == 0:
            return
        with obs_trace.span("spill_evict", cat="ooc",
                            blocks=int(blocks.size)) as sp:
            if self.on_evict is not None:
                self.on_evict()  # pins must copy the epoch before rows vanish
            all_rows = []
            spilled0 = self.bytes_spilled
            for b in blocks:
                b = int(b)
                rows = self._rows[b]
                if self.row_source is None or self._writer is not None:
                    payload = (self.row_source(rows)
                               if self.row_source is not None
                               else self._gather_device(rows))
                    if self.keep_host:
                        self._cache[b] = payload
                    if self._writer is not None:
                        self._writer.submit(b, payload)
                self.resident[b] = False
                self.bytes_spilled += self._payload_bytes(rows.size)
                all_rows.append(rows)
            self.spill_evictions += int(blocks.size)
            rows = np.concatenate(all_rows)
            tile = int(self.engine.plan.unified.src.shape[1])
            k = rows.size
            self.engine.update_edge_rows(
                rows,
                src=np.zeros((k, tile), np.int32),
                dst_local=np.zeros((k, tile), np.int32),
                w=np.zeros((k, tile), np.float32),
                valid=np.zeros((k, tile), bool))
            sp.set(bytes=int(self.bytes_spilled - spilled0))

    def fetch(self, blocks: np.ndarray) -> None:
        """Scatter blocks' true tile rows back into the device arrays and
        mark them resident. The scatter dispatch is asynchronous (JAX), so
        a boundary prefetch overlaps the following host work."""
        blocks = np.asarray(blocks, dtype=np.int64)
        blocks = blocks[~self.resident[blocks]]
        if blocks.size == 0:
            return
        with obs_trace.span("prefetch", cat="ooc",
                            blocks=int(blocks.size)) as sp:
            rows_l, parts = [], []
            for b in blocks:
                b = int(b)
                rows_l.append(self._rows[b])
                parts.append(self._payload_of(b))
                self.resident[b] = True
                self._cache.pop(b, None)
            rows = np.concatenate(rows_l)
            payload = {f: np.concatenate([p[f] for p in parts])
                       for f in self.PAYLOAD_FIELDS}
            fetched = self.engine.update_edge_rows(rows, **payload)
            self.bytes_fetched += fetched
            sp.set(bytes=int(fetched))

    # -- the per-superstep / per-boundary driver entry points ---------------
    def admit(self, need: np.ndarray, psd_blk: np.ndarray,
              calm_blk: np.ndarray | None) -> None:
        """Make the demand set resident before the superstep runs, evicting
        the calmest unprotected residents if the budget is full. Also
        enforces the budget itself (the first admit of a fresh engine
        spills the initial full-resident state down to the slot count).
        Counts hits (needed and already resident) vs misses (demand
        fetches the prefetcher failed to stage)."""
        need = np.asarray(need, dtype=np.int64)
        have = self.resident[need]
        self.prefetch_hits += int(have.sum())
        self.prefetch_misses += int(need.size - have.sum())
        missing = need[~have]
        protect = self.pinned.copy()
        protect[need] = True
        over = (int(self.resident.sum()) + int(missing.size) - self.budget)
        if over > 0:
            calm_blk = policy.fold_calm(calm_blk)
            victims = policy.rank_victims(
                psd_blk, calm_blk, self.resident, protect,
                self.retire_after, retired_only=False)
            self.evict(victims[:over])
        if missing.size:
            self.fetch(missing)

    def prefetch_boundary(self, need_next: np.ndarray, psd_blk: np.ndarray,
                          calm_blk: np.ndarray | None) -> int:
        """Repartition-boundary prefetch: stage the predicted next demand
        plus the hottest non-resident blocks beyond it, filling free slots
        first and then swapping out RETIRED residents only (a speculative
        fetch must never evict the live active set). Returns the number of
        blocks staged."""
        calm_blk = policy.fold_calm(calm_blk)
        protect = self.pinned.copy()
        protect[np.asarray(need_next, dtype=np.int64)] = True
        cand = policy.rank_fetch_candidates(psd_blk, self.resident,
                                            self.floor)
        # demand first (free, exact), then speculation by PSD rank
        need_next = np.asarray(need_next, dtype=np.int64)
        cand = np.concatenate(
            [need_next[~self.resident[need_next]],
             cand[~np.isin(cand, need_next)]])
        staged: list[int] = []
        free = self.budget - int(self.resident.sum())
        victims = policy.rank_victims(psd_blk, calm_blk, self.resident,
                                      protect, self.retire_after,
                                      retired_only=True)
        vi = 0
        for b in cand:
            if free > 0:
                free -= 1
            elif vi < victims.size:
                self.evict(victims[vi:vi + 1])
                vi += 1
            else:
                break
            staged.append(int(b))
        if staged:
            self.fetch(np.asarray(staged, dtype=np.int64))
        return len(staged)

    # -- epoch-pin support ---------------------------------------------------
    def materialize(self, ed: EdgeData) -> EdgeData:
        """Fill a deep-copied :class:`EdgeData`'s spilled holes with the
        true tile rows — what ``edge_snapshot`` hands a pinned epoch so
        snapshot isolation survives eviction. Residency is unchanged."""
        blocks = self.spilled_blocks
        if blocks.size == 0:
            return ed
        rows_l, parts = [], []
        for b in blocks:
            rows_l.append(self._rows[int(b)])
            parts.append(self._payload_of(int(b)))
        rows = np.concatenate(rows_l)
        payload = {f: np.concatenate([p[f] for p in parts])
                   for f in self.PAYLOAD_FIELDS}
        cov = tile_coverage(payload["dst_local"], payload["valid"],
                            self.engine.config.subblocks,
                            self.engine.plan.block_size)
        r = jnp.asarray(rows)
        return ed._replace(
            src=ed.src.at[r].set(jnp.asarray(payload["src"], jnp.int32)),
            dstl=ed.dstl.at[r].set(
                jnp.asarray(payload["dst_local"], jnp.int32)),
            w=ed.w.at[r].set(jnp.asarray(payload["w"], jnp.float32)),
            valid=ed.valid.at[r].set(jnp.asarray(payload["valid"], bool)),
            cov=ed.cov.at[r].set(jnp.asarray(cov)))

    def wait(self) -> None:
        """Drain the async segment writer (tests / clean shutdown)."""
        if self._writer is not None:
            self._writer.wait()
