"""Activity-based initial partitioning (paper Alg. 1, §3.2).

The vertices are sorted by active degree (descending), dead vertices moved to
the tail, and the live prefix is chunked into fixed-size *blocks* (the paper's
cache blocks; on TPU these are the VMEM-resident edge blocks). Because the
sort is a one-time permutation, every block is a contiguous vertex range and
its in-edges are a contiguous CSC range — dynamic repartitioning later only
re-labels blocks (barrier move / flag flip), never moves vertices, matching
the paper's O(n) bookkeeping claim.

Storage layouts:

  * per-group padded rows (:class:`EdgeStorage`): blocks padded to a common
    edge capacity per *storage group* (hot-born vs cold-born). Hot blocks
    contain the hubs and need a large capacity; cold blocks stay small.
    Used by the shard_map distributed engine.
  * unified tiled rows (:class:`TiledStorage`): every block's in-edges are
    chunked into fixed (TILE,)-wide tile rows, and each block owns a
    contiguous run of tile rows. One jitted function can process ANY block
    id (no host-side hot/cold routing) while compute stays proportional to
    the block's true edge count — padding a cold block (≈1e3 edges) to the
    hub block's capacity (≈1e5) would be an ~80x per-block blowup.

Padding is masked with a validity bit in both layouts, so any combine
(sum/min/max) stays exact.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.core import degrees
from repro.core.graph import Graph, permute
from repro.core.metrics import block_io_bytes


@dataclasses.dataclass(frozen=True)
class EdgeStorage:
    """Padded per-block in-edge arrays for one storage group.

    Shapes: (num_blocks, capacity). ``src`` indexes the *permuted* vertex
    space; ``dst_local`` is the destination offset within the block.
    """

    block_ids: np.ndarray  # (B,) global block id of each row
    src: np.ndarray  # (B, E) int32
    dst_local: np.ndarray  # (B, E) int32
    w: np.ndarray  # (B, E) float32
    valid: np.ndarray  # (B, E) bool
    edges: np.ndarray  # (B,) true edge count per block

    @property
    def num_blocks(self) -> int:
        return int(self.block_ids.shape[0])

    @property
    def capacity(self) -> int:
        return int(self.src.shape[1])


TILE = 512  # tile width of the unified layout (multiple of the 128 lanes)


@dataclasses.dataclass(frozen=True)
class TiledStorage:
    """Unified per-block in-edge tiles: block b owns tile rows
    [tile_start[b], tile_start[b] + tile_cnt[b]).

    Shapes: (n_tiles, TILE) for the edge arrays; (num_blocks,) for the
    per-block indices. ``src`` indexes the owning graph's vertex space;
    ``dst_local`` is the destination offset within the block.
    """

    src: np.ndarray  # (n_tiles, TILE) int32
    dst_local: np.ndarray  # (n_tiles, TILE) int32
    w: np.ndarray  # (n_tiles, TILE) float32
    valid: np.ndarray  # (n_tiles, TILE) bool
    tile_start: np.ndarray  # (num_blocks,) int32
    tile_cnt: np.ndarray  # (num_blocks,) int32
    edges: np.ndarray  # (num_blocks,) true edge count per block

    @property
    def num_blocks(self) -> int:
        return int(self.tile_start.shape[0])

    @property
    def tile(self) -> int:
        return int(self.src.shape[1])


def build_tiled_storage(g: Graph, block_size: int, num_blocks: int,
                        tile: int = TILE, slack: float = 0.0,
                        spare_tiles: int = 0) -> TiledStorage:
    """Chunk every block's contiguous CSC in-edge range into tile rows.

    ``slack``/``spare_tiles`` over-provision each block's tile run beyond its
    current edge count (capacity = ceil(edges * (1 + slack) / tile) +
    spare_tiles). The extra tiles are fully masked invalid, so results are
    unchanged; the streaming subsystem appends edge inserts into them in
    place, deferring a full rebuild until a block's run overflows.
    """
    counts = np.empty(num_blocks, dtype=np.int64)
    for b in range(num_blocks):
        lo, hi = b * block_size, min((b + 1) * block_size, g.n)
        counts[b] = int(g.in_indptr[hi] - g.in_indptr[lo])
    tile_cnt = -(-counts // tile)
    if slack > 0.0 or spare_tiles > 0:
        want = np.ceil(counts * (1.0 + slack) / tile).astype(np.int64)
        tile_cnt = np.maximum(tile_cnt, want) + spare_tiles
    tile_start = np.concatenate([[0], np.cumsum(tile_cnt)[:-1]])
    n_tiles = max(int(tile_cnt.sum()), 1)

    src = np.zeros((n_tiles, tile), dtype=np.int32)
    dstl = np.zeros((n_tiles, tile), dtype=np.int32)
    w = np.zeros((n_tiles, tile), dtype=np.float32)
    valid = np.zeros((n_tiles, tile), dtype=bool)
    for b in range(num_blocks):
        lo, hi = b * block_size, min((b + 1) * block_size, g.n)
        e0, e1 = int(g.in_indptr[lo]), int(g.in_indptr[hi])
        e = e1 - e0
        if e == 0:
            continue
        t0 = int(tile_start[b]) * tile
        flat = slice(t0, t0 + e)
        src.reshape(-1)[flat] = g.in_src[e0:e1]
        w.reshape(-1)[flat] = g.in_w[e0:e1]
        dst = np.repeat(np.arange(lo, hi, dtype=np.int64),
                        np.diff(g.in_indptr[lo:hi + 1]))
        dstl.reshape(-1)[flat] = (dst - lo).astype(np.int32)
        valid.reshape(-1)[flat] = True
    return TiledStorage(src=src, dst_local=dstl, w=w, valid=valid,
                        tile_start=tile_start.astype(np.int32),
                        tile_cnt=tile_cnt.astype(np.int32),
                        edges=counts)


@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    """Everything the engine needs after one-time preprocessing."""

    graph: Graph  # permuted graph
    inv: np.ndarray  # old->new vertex map (for reporting back)
    order: np.ndarray  # new->old vertex map
    block_size: int  # C, vertices per block
    num_blocks: int  # live blocks (excludes the dead tail)
    n_live: int
    n_dead: int
    barrier_block: int  # blocks [0, barrier) born hot, [barrier, P) born cold
    unified: TiledStorage  # all blocks, one layout (row index = block id)
    ad: np.ndarray  # AD in permuted order (diagnostics)
    t1: float  # AD threshold used
    alpha: float
    # Hierarchical partitions: every block is split into `subblocks`
    # contiguous vertex ranges of sub_size = block_size / subblocks each.
    # Sub-blocks are an ACTIVITY-TRACKING granularity (per-sub-block PSD,
    # calm counters, sweep masks), not a storage granularity — the tiled
    # layout is unchanged, and subblocks = 1 is the flat (PR-5) plan.
    subblocks: int = 1

    @property
    def sub_size(self) -> int:
        """Vertices per sub-block (block_size / subblocks, exact)."""
        return self.block_size // self.subblocks

    # Group-padded storages are only consumed by the shard_map distributed
    # engine (and its tests); built lazily so the common single-device path
    # never pays the O(blocks_in_group * group_max_edges) padding cost on
    # top of the unified layout.
    @functools.cached_property
    def hot(self) -> EdgeStorage:
        return _build_storage(
            self.graph, np.arange(0, self.barrier_block, dtype=np.int64),
            self.block_size)

    @functools.cached_property
    def cold(self) -> EdgeStorage:
        return _build_storage(
            self.graph,
            np.arange(self.barrier_block, self.num_blocks, dtype=np.int64),
            self.block_size)

    @property
    def dead_start(self) -> int:
        return self.n_live

    def block_range(self, b: int) -> tuple[int, int]:
        lo = b * self.block_size
        return lo, min(lo + self.block_size, self.n_live)

    def block_bytes(self, b: int) -> int:
        """I/O proxy: bytes loaded when block b is scheduled."""
        return int(block_io_bytes(int(self.unified.edges[b]),
                                  self.block_size))


def _build_storage(g: Graph, block_ids: np.ndarray, block_size: int,
                   pad_to: int | None = None) -> EdgeStorage:
    """Slice contiguous CSC ranges per block and pad to the group max."""
    counts = []
    for b in block_ids:
        lo, hi = b * block_size, min((b + 1) * block_size, g.n)
        counts.append(int(g.in_indptr[hi] - g.in_indptr[lo]))
    counts = np.asarray(counts, dtype=np.int64)
    cap = int(max(counts.max() if counts.size else 0, 1))
    if pad_to is not None:
        cap = max(cap, pad_to)
    # Round capacity to a lane-friendly multiple (TPU tiling: 128).
    cap = int(-(-cap // 128) * 128)

    nb = len(block_ids)
    src = np.zeros((nb, cap), dtype=np.int32)
    dstl = np.zeros((nb, cap), dtype=np.int32)
    w = np.zeros((nb, cap), dtype=np.float32)
    valid = np.zeros((nb, cap), dtype=bool)
    for r, b in enumerate(block_ids):
        lo, hi = b * block_size, min((b + 1) * block_size, g.n)
        e0, e1 = int(g.in_indptr[lo]), int(g.in_indptr[hi])
        e = e1 - e0
        src[r, :e] = g.in_src[e0:e1]
        w[r, :e] = g.in_w[e0:e1]
        # destination local offset: dst vertex - block start
        dst = np.repeat(np.arange(lo, hi, dtype=np.int64),
                        np.diff(g.in_indptr[lo:hi + 1]))
        dstl[r, :e] = (dst - lo).astype(np.int32)
        valid[r, :e] = True
    return EdgeStorage(block_ids=np.asarray(block_ids, dtype=np.int64),
                       src=src, dst_local=dstl, w=w, valid=valid,
                       edges=counts)


def build_plan(g: Graph, *, block_size: int = 256, alpha: float | None = None,
               sample_frac: float = 0.1, hot_ratio: float = 0.1,
               seed: int = 0, tile_slack: float = 0.0, spare_tiles: int = 0,
               keep_dead: bool = False, subblocks: int = 1) -> PartitionPlan:
    """Alg. 1: rank by AD, split hot/cold/dead, chunk into blocks.

    ``keep_dead`` routes zero-AD vertices into the live blocks (they sort to
    the tail anyway) instead of the unscheduled dead partition — required by
    the streaming subsystem, where an isolated vertex can gain edges later
    and must already own a block slot + spare tile capacity.

    ``subblocks`` splits every block into that many equal contiguous
    sub-ranges for sub-block activity tracking (see PartitionPlan); it must
    divide ``block_size`` so every sub-block is the same size.
    """
    if subblocks < 1 or block_size % subblocks:
        raise ValueError(
            f"subblocks ({subblocks}) must be >= 1 and divide "
            f"block_size ({block_size})")
    if alpha is None:
        alpha = degrees.suggest_alpha(g)
    ad = degrees.active_degree(g, alpha)
    t1 = degrees.sampled_threshold(ad, sample_frac, hot_ratio, seed)

    dead = np.zeros(g.n, dtype=bool) if keep_dead else (ad <= 0.0)
    n_dead = int(dead.sum())
    live_order = np.argsort(-ad[~dead], kind="stable")
    live_ids = np.flatnonzero(~dead)[live_order]
    order = np.concatenate([live_ids, np.flatnonzero(dead)])
    pg, inv = permute(g, order)
    ad_perm = ad[order]

    n_live = g.n - n_dead
    num_blocks = max(-(-n_live // block_size), 1) if n_live else 0
    # Hot prefix: blocks whose FIRST vertex clears T1 (AD-descending order
    # means hotness decays along the block index).
    barrier = 0
    for b in range(num_blocks):
        if ad_perm[b * block_size] >= t1 and t1 > 0:
            barrier = b + 1
        else:
            break
    if num_blocks and barrier == 0 and n_live:
        barrier = 1  # always at least one hot block to seed the schedule

    unified = build_tiled_storage(pg, block_size, num_blocks,
                                  slack=tile_slack, spare_tiles=spare_tiles)
    return PartitionPlan(graph=pg, inv=inv, order=order, block_size=block_size,
                         num_blocks=num_blocks, n_live=n_live, n_dead=n_dead,
                         barrier_block=barrier, unified=unified, ad=ad_perm,
                         t1=t1, alpha=alpha, subblocks=subblocks)
