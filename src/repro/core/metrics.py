"""Accounting the paper evaluates on: runtime, updates, partition loads.

On TPU/CPU we cannot read an L3-miss counter, but the schedule makes the
quantity *exact*: every scheduled block is one partition load (HBM->VMEM
refill of its edge slice + vertex slice). ``bytes_loaded`` is the I/O proxy
(paper §2.1), ``updates`` the convergence-work proxy (§2.2 contribution 1).
"""
from __future__ import annotations

import dataclasses
import time


# Order of the accounting vector the fused engine flushes at repartition
# boundaries: the device accumulates exact per-block schedule counts, the
# host expands them through a per-block [vertices, edges, loads, bytes]
# table into this layout.
COUNTER_FIELDS = ("updates", "edges_processed", "block_loads",
                  "bytes_loaded")


@dataclasses.dataclass
class Metrics:
    iterations: int = 0
    updates: int = 0  # vertex apply() executions
    edges_processed: int = 0
    block_loads: int = 0  # partition loads (cache/I-O proxy)
    bytes_loaded: int = 0
    wall_time_s: float = 0.0
    converged: bool = False

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def absorb_counters(self, counters) -> None:
        """Add a (len(COUNTER_FIELDS),) device-counter flush (cumulative
        deltas, COUNTER_FIELDS order)."""
        for name, v in zip(COUNTER_FIELDS, counters):
            setattr(self, name, getattr(self, name) + int(round(float(v))))


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.elapsed = time.perf_counter() - self.t0
