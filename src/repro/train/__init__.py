from repro.train.step import TrainState, loss_fn, make_train_step

__all__ = ["TrainState", "loss_fn", "make_train_step"]
