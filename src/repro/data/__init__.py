from repro.data.pipeline import SyntheticLM

__all__ = ["SyntheticLM"]
