"""Multi-lane fused execution: L independent queries per superstep sweep.

The :class:`LaneEngine` is the query-serving generalization of
``StructureAwareEngine._run_fused``: vertex values carry a lane axis
``(values_len, L)``, one jitted ``lax.while_loop`` advances every lane per
superstep, and the paper's whole scheduling stack prices the **union** of
the lane frontiers:

  * the per-block scheduling priority is the max over live lanes of the
    per-lane PSD (``state.fold_lane_psd``) — a block hot in ANY running
    lane is schedulable, so one hot dispatch serves every lane that needs
    the block;
  * **per-lane convergence masks** retire finished lanes: lane l is done
    when SUM_b PSD[b, l] < T2 (the paper's test, per lane). A retired
    lane stops contributing to block priority, so the active set — and
    with it the adaptive dispatch width — shrinks as lanes finish;
  * the adaptive active-set machinery (calm/retire counters, PSD-rank
    depth ladder, dispatch-width buckets) is REUSED via the engine's
    module-level decision helpers, not reimplemented — with a single
    admitted lane the schedule decisions are identical to the
    single-program engine, which is what makes serving a strict superset
    of the engine rather than a fork (property tested);
  * hierarchical partitions (``EngineConfig.subblocks = S > 1``) carry
    through: lane PSD/dmax are (P, S, L), calm is (P, S), and each
    scheduled block applies ONE shared (S,) sub-block mask — the
    lane-folded sub priority over the pruning floor
    (:func:`repro.core.state.lane_sub_psd_device`) — so a narrow query
    frontier sweeps only the sub-ranges some live lane actually prices,
    instead of paying whole-block sweeps. ``subblocks = 1`` traces the
    exact flat path.

Why lanes beat sequential runs: each scheduled block's edge tiles are
gathered once per superstep and the message/combine/apply math vectorizes
over the lane axis, so L queries share every partition load, every
schedule decision, and every while-loop step. Partition loads and bytes
are billed once per block schedule (the load IS shared); ``updates`` and
``edges_processed`` are billed per admitted lane (the arithmetic is not).

Everything per-epoch (edge tiles, aux, coupling) and per-batch (init
values, personalization vconst) arrives as TRACED ARGUMENTS, so one
compiled executable per (family, lane width, dispatch bucket) serves
every batch and every streaming epoch of the same tile geometry.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.analysis.contracts import one_executable_per
from repro.core import state as state_lib
from repro.core.algorithms import LaneProgram
from repro.core.engine import (EdgeData, StructureAwareEngine, acct_table,
                               dispatch_width, inner_depths,
                               make_lane_processor)
from repro.core.metrics import Metrics, Timer
from repro.core.repartition import RepartitionState
from repro.core.schedule import make_device_select


@dataclasses.dataclass
class LaneResult:
    values: np.ndarray  # (n, L), original vertex ids
    metrics: Metrics  # batch-level accounting (see module docstring)
    lane_iterations: np.ndarray  # (L,) supersteps until each lane converged
    lane_converged: np.ndarray  # (L,) bool


class LaneEngine:
    """Fused multi-lane runner over one engine epoch's tile geometry.

    Borrows plan, config, and the compiled-decision helpers from a
    :class:`StructureAwareEngine` (the geometry owner); edge state and
    coupling arrive per run, so the same LaneEngine serves every epoch
    that keeps the geometry (a plan rebuild needs a new one, exactly like
    the engine's own compiled functions)."""

    def __init__(self, engine: StructureAwareEngine, program: LaneProgram,
                 use_pallas: bool | None = None):
        self.engine = engine
        self.program = program
        # None inherits the geometry owner's flag, so a Pallas engine
        # serves Pallas lanes without the caller re-stating it
        self.use_pallas = (engine.config.use_pallas if use_pallas is None
                           else use_pallas)
        p = engine.plan
        self._proc = make_lane_processor(program, p.unified, p.block_size,
                                         p.n_live, p.graph.n,
                                         subblocks=engine.config.subblocks,
                                         use_pallas=self.use_pallas)
        self._fns: dict = {}

    # -- traced pieces (mirrors of the engine's, with a lane axis) -----------
    def _sweeps(self, width: int):
        eng = self.engine
        c = eng.plan.block_size
        subblocks = eng.config.subblocks
        floor = jnp.float32(eng._psd_floor())
        depths = jnp.asarray(inner_depths(eng.config, width))
        process_one, process_iterated, gids = self._proc

        def write_one(values, psd, dmax, base, new, psd_vec, dmax_vec, gid,
                      ok, sub_act=None):
            nl = values.shape[1]
            cur = lax.dynamic_slice(values, (base, 0), (c, nl))
            values = lax.dynamic_update_slice(
                values, jnp.where(ok, new, cur), (base, 0))
            if sub_act is not None:
                # masked sub-blocks keep their prior per-lane PSD/dmax —
                # they were not swept, so their staleness is unchanged
                psd_vec = jnp.where(sub_act[:, None], psd_vec, psd[gid])
                dmax_vec = jnp.where(sub_act[:, None], dmax_vec, dmax[gid])
            psd = jnp.where(ok, psd.at[gid].set(psd_vec), psd)
            dmax = jnp.where(ok, dmax.at[gid].set(dmax_vec), dmax)
            return values, psd, dmax

        def row_sub_act(psd, lane_done, gid):
            """(S,) shared sub-block mask for one scheduled row: the
            lane-folded sub priority over the floor. Rows scheduled in a
            superstep are distinct and each sweep writes only its own
            row, so reading ``psd[gid]`` mid-sweep equals the
            pre-superstep fold the sb accounting uses."""
            live = jnp.max(jnp.where(lane_done, jnp.float32(0.0),
                                     psd[gid]), axis=-1)
            return live >= floor

        def hot_sweep(ed, vconst, values, psd, dmax, rows, ok, lane_done):
            def body(i, carry):
                values, psd, dmax = carry
                row = rows[i]
                sub_act = (None if subblocks == 1
                           else row_sub_act(psd, lane_done, gids[row]))
                base, new, pv, dv = process_iterated(ed, values, vconst,
                                                     row, depths[i],
                                                     sub_act)
                return write_one(values, psd, dmax, base, new, pv, dv,
                                 gids[row], ok[i], sub_act)
            return lax.fori_loop(0, width, body, (values, psd, dmax))

        def cold_sweep(ed, vconst, values, psd, dmax, rows, ok, lane_done):
            if subblocks == 1:
                bases, news, pvs, dvs = jax.vmap(
                    lambda r: process_one(ed, values, vconst, r))(rows)
                sub_acts = [None] * width
            else:
                sub_acts = jax.vmap(
                    lambda r: row_sub_act(psd, lane_done, gids[r]))(rows)
                bases, news, pvs, dvs = jax.vmap(
                    lambda r, sa: process_one(ed, values, vconst, r, sa))(
                        rows, sub_acts)

            def body(i, carry):
                values, psd, dmax = carry
                sa = None if subblocks == 1 else sub_acts[i]
                return write_one(values, psd, dmax, bases[i], news[i],
                                 pvs[i], dvs[i], gids[rows[i]], ok[i], sa)
            return lax.fori_loop(0, width, body, (values, psd, dmax))

        return hot_sweep, cold_sweep

    def _make_post(self):
        eng = self.engine
        eps = eng.config.stale_eps
        floor = eng._psd_floor()

        def post(coupling, psd, dmax, calm, lane_done):
            """Per-lane staleness propagation + the SHARED calm counters:
            the bump is applied lane-by-lane (a delta in lane l re-arms
            downstream blocks for lane l only), while retirement hysteresis
            tracks the folded (lane-union) priority — a block retires only
            when quiet in every live lane, which keeps the active set sound
            for the whole batch. With a sub-block axis ((P, S, L) state)
            the coupling is destination-sub-resolved ((P, P, S), same as
            the engine's post): the outgoing signal is the block's max
            sub-delta per lane and an incoming bump re-arms only the
            sub-ranges that block actually feeds, per lane; calm then
            advances per sub-block on the lane-folded sub priority."""
            d = jnp.where(dmax > eps, dmax, 0.0)  # (P, L) or (P, S, L)
            if psd.ndim == 3:
                dblk = d.max(axis=1)  # (P, L)
                bump = jnp.max(dblk[:, None, None, :]
                               * coupling[:, :, :, None], axis=0)  # (P,S,L)
                psd = jnp.maximum(psd, jnp.minimum(bump, 1e29))
                quiet = state_lib.lane_sub_psd_device(psd, lane_done)
            else:
                bump = jnp.max(d[:, None, :] * coupling[:, :, None], axis=0)
                psd = jnp.maximum(psd, jnp.minimum(bump, 1e29))
                quiet = state_lib.fold_lane_psd_device(psd, lane_done)
            calm = jnp.where(quiet < floor, calm + 1, 0).astype(jnp.int32)
            return psd, jnp.zeros_like(dmax), calm
        return post

    @one_executable_per("width")
    def _get_chunk(self, width: int):
        key = ("lane_chunk", width)
        if key in self._fns:
            return self._fns[key]
        eng = self.engine
        cfg, plan = eng.config, eng.plan
        t2 = cfg.t2
        hot_sweep, cold_sweep = self._sweeps(width)
        post = self._make_post()
        tile_cnt = plan.unified.tile_cnt
        select = make_device_select(
            width=width, cold_frac=cfg.cold_frac, min_psd=eng._psd_floor(),
            pad_id=int(np.argmin(tile_cnt)) if tile_cnt.size else 0)

        floor = jnp.float32(eng._psd_floor())

        def chunk(ed, coupling, vconst, values, psd, dmax, calm, counts,
                  hslots, sbacc, lane_done, lane_it, it0, it_end, is_hot,
                  i2):
            def cond(carry):
                it = carry[0]
                done = carry[-1]
                return (it < it_end) & jnp.logical_not(done)

            def body(carry):
                (it, values, psd, dmax, calm, counts, hslots, sbacc,
                 lane_done, lane_it, _) = carry
                block_psd = state_lib.fold_lane_psd_device(psd, lane_done)
                hot_rows, hot_ok, cold_rows, cold_ok = select(
                    it, i2, block_psd, is_hot)
                # sub-block dispatch accounting from the PRE-superstep
                # priorities — identical to the masks the sweeps apply
                # (scheduled rows are distinct within a superstep)
                if psd.ndim == 3:
                    live = (state_lib.lane_sub_psd_device(psd, lane_done)
                            >= floor).sum(axis=-1).astype(jnp.int32)
                else:
                    live = (block_psd >= floor).astype(jnp.int32)
                sbacc = sbacc + \
                    jnp.where(hot_ok, live[hot_rows], 0).sum() + \
                    jnp.where(cold_ok, live[cold_rows], 0).sum()
                values, psd, dmax = hot_sweep(ed, vconst, values, psd,
                                              dmax, hot_rows, hot_ok,
                                              lane_done)
                values, psd, dmax = cold_sweep(ed, vconst, values, psd,
                                               dmax, cold_rows, cold_ok,
                                               lane_done)
                counts = counts.at[hot_rows].add(hot_ok.astype(jnp.int32))
                counts = counts.at[cold_rows].add(cold_ok.astype(jnp.int32))
                hslots = hslots + hot_ok.astype(jnp.int32)
                psd, dmax, calm = post(coupling, psd, dmax, calm, lane_done)
                lane_conv = state_lib.lane_converged_device(psd, t2)
                scheduled = hot_ok.any() | cold_ok.any()
                it = it + jnp.where(scheduled, 1, 0).astype(it.dtype)
                newly = lane_conv & jnp.logical_not(lane_done)
                lane_it = jnp.where(newly, it, lane_it)
                lane_done = lane_done | lane_conv
                done = lane_done.all() | jnp.logical_not(scheduled)
                return (it, values, psd, dmax, calm, counts, hslots, sbacc,
                        lane_done, lane_it, done)

            (it, values, psd, dmax, calm, counts, hslots, sbacc, lane_done,
             lane_it, _) = lax.while_loop(
                cond, body,
                (it0, values, psd, dmax, calm, counts, hslots, sbacc,
                 lane_done, lane_it, jnp.bool_(False)))
            return (it, values, psd, dmax, calm, counts, hslots, sbacc,
                    lane_done, lane_it, lane_done.all())

        fn = jax.jit(chunk, donate_argnums=(3, 4, 5, 6, 7, 8, 9, 10, 11))
        self._fns[key] = fn
        return fn

    # -- host side -----------------------------------------------------------
    def _pad_lane_values(self, arr: np.ndarray) -> np.ndarray:
        pad = self.engine._values_len - arr.shape[0]
        if pad:
            return np.concatenate(
                [arr, np.zeros((pad, arr.shape[1]), dtype=arr.dtype)])
        return arr

    def _init_dead(self, values0: np.ndarray, vconst: np.ndarray):
        """Dead partition one-shot (engine parity): apply() with the
        identity aggregate, per lane. Streaming plans keep zero dead
        vertices; this covers LaneEngines over plain engines."""
        p = self.engine.plan
        if p.n_dead == 0:
            return values0
        dead = slice(p.n_live, p.graph.n)
        nl = values0.shape[1]
        agg = jnp.full((p.n_dead, nl),
                       0.0 if self.program.combine == "sum"
                       else self.program.identity, jnp.float32)
        values0 = values0.copy()
        values0[dead] = np.asarray(self.program.apply(
            jnp.asarray(values0[dead]), agg, jnp.asarray(vconst[dead]),
            p.graph.n))
        return values0

    def prewarm(self, n_lanes: int) -> list[int]:
        """Compile the lane chunk for every dispatch-width bucket at this
        lane width with a zero-length run, so no query batch pays a
        compile inside its measured latency (streaming-prewarm parity)."""
        eng = self.engine
        p = eng.plan
        vl = eng._values_len
        sb = eng.config.subblocks
        lane_shape = ((p.num_blocks, n_lanes) if sb == 1
                      else (p.num_blocks, sb, n_lanes))
        calm_shape = (p.num_blocks,) if sb == 1 else (p.num_blocks, sb)
        for wb in eng._ladder:
            fn = self._get_chunk(wb)
            fn(eng.edge_state, jnp.zeros(eng._coupling.shape, jnp.float32),
               jnp.zeros((vl, n_lanes), jnp.float32),
               jnp.zeros((vl, n_lanes), jnp.float32),
               jnp.zeros(lane_shape, jnp.float32),
               jnp.zeros(lane_shape, jnp.float32),
               jnp.zeros(calm_shape, jnp.int32),
               jnp.zeros(p.num_blocks, jnp.int32),
               jnp.zeros(wb, jnp.int32),
               jnp.int32(0),
               jnp.zeros(n_lanes, dtype=bool),
               jnp.zeros(n_lanes, jnp.int32),
               jnp.int32(0), jnp.int32(0),
               jnp.zeros(p.num_blocks, dtype=bool),
               jnp.int32(eng.config.i2))
        return list(eng._ladder)

    def run(self, *, ed: EdgeData, coupling: np.ndarray,
            values0: np.ndarray, vconst: np.ndarray | None,
            lane_active: np.ndarray, edge_counts: np.ndarray,
            max_iterations: int | None = None) -> LaneResult:
        """Run every active lane to convergence over the given epoch state.

        ``values0``/``vconst`` are (n, L) in ORIGINAL vertex ids;
        ``lane_active`` marks admitted lanes (padding lanes start
        individually converged and never price a block); ``edge_counts``
        is the pinned epoch's per-block live edge counts (metric truth).
        """
        eng = self.engine
        cfg, p = eng.config, eng.plan
        max_it = max_iterations or cfg.max_iterations
        lane_active = np.asarray(lane_active, dtype=bool)
        nl = values0.shape[1]
        n_adm = int(lane_active.sum())

        vals = np.asarray(values0, dtype=np.float32)[p.order]
        vc = (np.asarray(vconst, dtype=np.float32)[p.order]
              if vconst is not None
              else np.zeros_like(vals))
        vals = self._init_dead(vals, vc)
        values = jnp.asarray(self._pad_lane_values(vals))
        vconst_dev = jnp.asarray(self._pad_lane_values(vc))

        sb = cfg.subblocks
        psd_host = state_lib.init_lane_psd(p.num_blocks, lane_active,
                                           None if sb == 1 else sb)
        psd = jnp.asarray(psd_host)
        lane_done_host = ~lane_active
        lane_done = jnp.asarray(lane_done_host)
        lane_it = jnp.zeros(nl, jnp.int32)
        folded = state_lib.fold_lane_psd(psd_host, lane_done_host)
        mode = ("barrier" if self.program.monotone_cooling else "universal")
        rep = RepartitionState.create(
            p.num_blocks, p.barrier_block, mode,
            interval=cfg.repartition_interval,
            growth=cfg.repartition_growth)
        calm_host = np.zeros(p.num_blocks if sb == 1 else (p.num_blocks, sb),
                             dtype=np.int32)
        calm = jnp.asarray(calm_host)
        dmax = jnp.zeros((p.num_blocks, nl) if sb == 1
                         else (p.num_blocks, sb, nl), jnp.float32)
        active = eng._active_count(calm_host)
        # loads/bytes are billed once per block schedule (shared by the
        # lanes — that is the batching win); updates/edges per admitted
        # lane (the arithmetic really runs per lane)
        acct = acct_table(p, edge_counts)
        acct[:, 0] *= max(n_adm, 1)
        acct[:, 1] *= max(n_adm, 1)
        coupling_dev = jnp.asarray(np.asarray(coupling, dtype=np.float32))
        metrics = Metrics()
        depth_hist: dict[int, int] = {}
        width_iters = 0
        sb_total = 0
        loads_total = 0
        conv = jnp.bool_(False)

        with Timer() as t:
            it = 0
            while it < max_it and n_adm:
                wb = dispatch_width(cfg, eng._ladder, active, folded)
                chunk = self._get_chunk(wb)
                it_end = rep.chunk_end(max_it)
                (it_dev, values, psd, dmax, calm, counts, hslots, sbacc,
                 lane_done, lane_it, conv) = chunk(
                    ed, coupling_dev, vconst_dev, values, psd, dmax, calm,
                    jnp.zeros(p.num_blocks, jnp.int32),
                    jnp.zeros(wb, jnp.int32),
                    jnp.int32(0),
                    lane_done, lane_it,
                    jnp.int32(it), jnp.int32(it_end),
                    jnp.asarray(rep.is_hot), jnp.int32(cfg.i2))
                it_new = int(it_dev)
                psd_host = np.asarray(psd)
                lane_done_host = np.asarray(lane_done)
                calm_host = np.asarray(calm)
                # ONE active-set read per chunk boundary: both the next
                # dispatch-width pick and the end-of-run retirement metric
                # reuse it (this used to be recomputed at every use site)
                active = eng._active_count(calm_host)
                folded = state_lib.fold_lane_psd(psd_host, lane_done_host)
                counts_host = np.asarray(counts, dtype=np.int64)
                metrics.absorb_counters(counts_host @ acct)
                sb_total += int(sbacc)
                loads_total += int(counts_host.sum())
                span = it_new - it
                width_iters += wb * span
                for d, cnt in zip(inner_depths(cfg, wb).tolist(),
                                  np.asarray(hslots).tolist()):
                    if cnt:
                        depth_hist[int(d)] = depth_hist.get(int(d), 0) + \
                            int(cnt)
                if bool(conv):
                    metrics.converged = True
                    it = it_new
                    break
                if it_new == it:  # schedule went empty
                    break
                it = it_new
                rep.maybe_repartition(it - 1, folded, cfg.hot_ratio)
        metrics.iterations = it
        metrics.wall_time_s = t.elapsed
        metrics.mean_dispatch_width = width_iters / max(it, 1)
        metrics.blocks_retired = p.num_blocks - active
        metrics.subblocks_retired = eng._subblocks_retired(calm_host)
        metrics.mean_subblock_dispatch = sb_total / max(loads_total, 1)
        metrics.inner_depth_hist = depth_hist
        lane_it_host = np.asarray(lane_it, dtype=np.int64)
        lane_conv_host = np.asarray(lane_done) & lane_active
        lane_iters = np.where(lane_conv_host, lane_it_host, it)
        out = np.asarray(values)[p.inv]  # (n, L), original ids
        return LaneResult(values=out, metrics=metrics,
                          lane_iterations=lane_iters,
                          lane_converged=lane_conv_host)
