"""Structure-aware expert rebalancing at runtime (the paper's technique,
applied beyond paper — DESIGN.md §4).

Mapping: experts are vertices; tokens routed to an expert are its in-edges;
EP shards are the partitions. The paper's moves become:

  * activity degree  -> EMA routed-token count blended with instantaneous
                        load (Eq. 1's D_o + alpha*D_i re-read);
  * dynamic repartitioning on a growing cadence (I1) -> periodic greedy
    re-binning of experts onto EP shards by activity (rebalance_plan);
  * O(n) bookkeeping -> permuting the expert axis of the MoE params (and
    optimizer moments) together with the router columns, which is
    FUNCTION-PRESERVING (the model computes exactly the same outputs; only
    the shard each expert lives on changes — tested).

The payoff at scale: the EP all-to-all's critical path is bounded by the
hottest shard's token count; balanced shards cut straggling exactly as the
paper's hot/cold balancing cuts cache thrash.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.models import moe as moe_lib


def permute_expert_axis(params: dict, perm: np.ndarray) -> dict:
    """Relabel experts: slot perm[i] <- expert i, for every (L, E, ...) MoE
    tensor and the router's output columns. Function-preserving."""
    inv = np.argsort(perm)  # new slot j holds old expert inv[j]

    def one_layer_tree(moe):
        out = dict(moe)
        for k in ("w_gate", "w_up", "w_down"):
            out[k] = moe[k][:, inv]  # (L, E, ...) expert axis
        out["router"] = moe["router"][:, :, inv]  # (L, D, E) output cols
        return out

    new = dict(params)
    new_layers = dict(params["layers"])
    new_layers["moe"] = one_layer_tree(params["layers"]["moe"])
    new["layers"] = new_layers
    return new


@dataclasses.dataclass
class ExpertRebalancer:
    """Paper Alg. 2's cadence, for experts: observe loads, re-bin on a
    growing interval when the predicted imbalance justifies the move."""

    num_experts: int
    num_shards: int
    alpha: float = 0.75  # Eq. 1 blend
    ema: float = 0.9
    interval: int = 50  # I1: steps between rebalance checks
    growth: float = 1.5  # the paper's growing cadence
    min_gain: float = 0.05  # skip moves worth <5% imbalance reduction
    load_ema: np.ndarray | None = None
    next_at: int = 0
    moves: int = 0

    def __post_init__(self):
        if self.load_ema is None:
            self.load_ema = np.zeros(self.num_experts)
        self.next_at = self.interval

    def shard_imbalance(self, activity: np.ndarray) -> float:
        """max-shard / mean-shard predicted load under current placement."""
        per = self.num_experts // self.num_shards
        loads = activity.reshape(self.num_shards, per).sum(1)
        return float(loads.max() / max(loads.mean(), 1e-9))

    def observe(self, expert_load: np.ndarray, step: int):
        """Feed this step's (E,) routed-token counts. Returns a permutation
        (slot perm[i] <- expert i) when a rebalance should happen, else
        None. Caller applies it with permute_expert_axis to params AND
        optimizer moments, then resets its jitted step (shapes unchanged,
        so no recompile is actually triggered)."""
        activity, self.load_ema = moe_lib.expert_activity(
            self.load_ema, np.asarray(expert_load, np.float64),
            alpha=self.alpha, ema=self.ema)
        if step < self.next_at:
            return None
        self.interval = max(int(np.ceil(self.interval * self.growth)),
                            self.interval + 1)
        self.next_at = step + self.interval
        before = self.shard_imbalance(activity)
        perm = moe_lib.rebalance_plan(activity, self.num_shards)
        after = self.shard_imbalance(activity[np.argsort(perm)])
        if before - after < self.min_gain * before:
            return None
        self.moves += 1
        return perm
