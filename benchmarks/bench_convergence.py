"""Paper Figure 5 analogue: vertex-convergence curves per iteration.

Emits the PSD-sum (residual activity) and scheduled-block trajectories for
both engines; the derived column carries the curve downsampled to 8 points
so the table stays printable. Full curves land in results/convergence/.
"""
from __future__ import annotations

import json
import os

from repro.core import algorithms as A
from repro.core import graph as G
from repro.core.baseline import BaselineEngine
from repro.core.engine import EngineConfig, StructureAwareEngine


def _curve(history, key):
    return [round(float(h[key]), 10) for h in history]


def _downsample(xs, k=8):
    if len(xs) <= k:
        return xs
    step = len(xs) / k
    return [xs[int(i * step)] for i in range(k)]


def run(n: int = 20000, outdir: str = "results/convergence"):
    os.makedirs(outdir, exist_ok=True)
    cfg = EngineConfig(t2=1e-8, width=16, block_size=512)
    g = G.core_periphery_graph(n, avg_deg=8, seed=1, chords=1)
    rows = []
    for aname, mk in [("pagerank", A.pagerank), ("sssp", lambda: A.sssp(0))]:
        base = BaselineEngine(g, mk(), cfg, frontier=False).run()
        # host-driven loop: this suite plots PER-ITERATION trajectories,
        # which the fused loop's boundary-granular history cannot provide
        sa = StructureAwareEngine(g, mk(), cfg).run(fused=False)
        curves = {
            "base_psd": _curve(base.history, "psd_sum"),
            "base_active": _curve(base.history, "active"),
            "sa_psd": _curve(sa.history, "psd_sum"),
            "sa_scheduled": _curve(sa.history, "scheduled"),
            "sa_hot_blocks": _curve(sa.history, "hot_blocks"),
        }
        with open(os.path.join(outdir, f"{aname}.json"), "w") as f:
            json.dump(curves, f)
        rows.append((f"convergence/{aname}/base",
                     base.metrics.wall_time_s * 1e6,
                     "psd8=" + ",".join(f"{x:.1e}" for x in
                                        _downsample(curves["base_psd"]))))
        rows.append((f"convergence/{aname}/sa",
                     sa.metrics.wall_time_s * 1e6,
                     "psd8=" + ",".join(f"{x:.1e}" for x in
                                        _downsample(curves["sa_psd"]))))
    return rows
