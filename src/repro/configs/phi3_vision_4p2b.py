"""phi-3-vision-4.2b [vlm]: phi3-mini backbone (32L d=3072 32H kv=32
ff=8192) + CLIP tower STUB: input_specs provides 1024 precomputed patch
embeddings prepended to the text sequence.
[hf:microsoft/Phi-3-vision-128k-instruct; hf]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b", family="vlm",
    num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32064,
    num_patches=1024,
)
