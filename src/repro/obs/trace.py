"""Host-side span tracer: nested spans into a bounded ring buffer.

The paper's thesis is that SCHEDULING decisions drive runtime — so the
interesting question about any run is *when* things happened (when a
block retired, when a re-arm wave fired, which ingest stalled a serve
batch), not just the end-of-run totals the ``Metrics`` classes carry.
This module is the host half of the observability layer:

  * :class:`TraceRecorder` — structured events (spans, instants, counter
    rows) appended to a ``deque`` ring buffer; overflow drops the OLDEST
    events and counts them (``dropped``), so a long-lived service can
    keep a recorder installed forever at bounded memory.
  * module-level ``install()`` / ``current()`` / ``recording()`` — the
    engines look the recorder up per call; with none installed,
    :func:`span` returns a shared no-op context whose cost is one global
    read, which is what keeps the instrumented hot paths free when
    tracing is off.
  * :func:`span` — nested-span context manager. The yielded handle
    carries ``t0``/``t1`` (seconds, relative to the recorder epoch) and
    ``set(**args)`` for results only known at exit (e.g. whether a
    repartition boundary actually fired).

All clock reads live HERE, not at the instrumented call sites: the
schedule-affecting modules (``ooc/store.py`` and friends) are under the
RA004 no-clocks lint rule, and routing their spans through this module
keeps them clock-free while still timestamping their events. Nothing in
this module imports jax or touches device state — recording a span can
never perturb a trajectory (bitwise parity with tracing on is
property-tested in ``tests/test_obs.py``).

Timestamps are ``time.perf_counter()`` deltas (monotonic) against the
recorder's construction epoch; the Chrome-trace exporter
(:mod:`repro.obs.export`) converts to microseconds.
"""
from __future__ import annotations

import time
from collections import deque
from contextlib import contextmanager

DEFAULT_CAPACITY = 65536  # events kept before the ring starts dropping


class SpanHandle:
    """Mutable view of an open span: ``set(**kw)`` attaches result args;
    ``t0``/``t1`` expose the measured window after the ``with`` exits
    (the engine interpolates per-superstep counter timestamps from
    them)."""

    __slots__ = ("name", "cat", "args", "t0", "t1")

    def __init__(self, name: str, cat: str, args: dict):
        self.name = name
        self.cat = cat
        self.args = args
        self.t0 = 0.0
        self.t1 = 0.0

    def set(self, **kw) -> None:
        self.args.update(kw)


class _NullSpan:
    """Shared do-nothing handle for the tracing-off path."""

    __slots__ = ()
    t0 = 0.0
    t1 = 0.0

    def set(self, **kw) -> None:
        pass


class _NullContext:
    """Reusable no-op context manager: one global read + one attribute
    call is the whole cost of an un-recorded span."""

    __slots__ = ()

    def __enter__(self):
        return _NULL_SPAN

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()
_NULL_CONTEXT = _NullContext()


class TraceRecorder:
    """Ring buffer of structured trace events.

    Event shapes (plain dicts, exporter-agnostic):
      ``{"type": "span", "name", "cat", "ts", "dur", "depth", "args"}``
      ``{"type": "instant", "name", "cat", "ts", "args"}``
      ``{"type": "counter", "name", "cat", "ts", "values"}``
    ``ts``/``dur`` are seconds relative to the recorder epoch.
    """

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        self.capacity = int(capacity)
        self.events: deque = deque(maxlen=self.capacity)
        self.dropped = 0  # oldest events evicted by the ring
        self._epoch = time.perf_counter()
        self._depth = 0

    def now(self) -> float:
        return time.perf_counter() - self._epoch

    def _push(self, ev: dict) -> None:
        if len(self.events) == self.capacity:
            self.dropped += 1
        self.events.append(ev)

    @contextmanager
    def span(self, name: str, cat: str = "", **args):
        h = SpanHandle(name, cat, dict(args))
        h.t0 = self.now()
        self._depth += 1
        try:
            yield h
        finally:
            self._depth -= 1
            h.t1 = self.now()
            self._push({"type": "span", "name": name, "cat": cat,
                        "ts": h.t0, "dur": h.t1 - h.t0,
                        "depth": self._depth, "args": h.args})

    def instant(self, name: str, cat: str = "", **args) -> None:
        self._push({"type": "instant", "name": name, "cat": cat,
                    "ts": self.now(), "args": dict(args)})

    def counter_rows(self, name: str, rows: list, t0: float, t1: float,
                     cat: str = "engine") -> None:
        """Emit one counter event per row, timestamps interpolated
        UNIFORMLY across ``[t0, t1]``. This is how the fused engine's
        per-superstep timeline (exact counters, flushed once per chunk at
        the existing boundary sync) lands on the time axis: the counter
        VALUES are exact, their placement within the chunk's wall window
        is interpolated — the device does not timestamp supersteps."""
        k = len(rows)
        if k == 0:
            return
        step = (t1 - t0) / k
        for i, row in enumerate(rows):
            self._push({"type": "counter", "name": name, "cat": cat,
                        "ts": t0 + i * step,
                        "values": {k2: v for k2, v in row.items()
                                   if isinstance(v, (int, float))
                                   and not isinstance(v, bool)}})


# -- module-level installation ----------------------------------------------
_CURRENT: TraceRecorder | None = None


def install(recorder: TraceRecorder) -> TraceRecorder:
    """Make ``recorder`` the process-wide target of :func:`span` /
    :func:`instant`. Returns it (chaining convenience)."""
    global _CURRENT
    _CURRENT = recorder
    return recorder


def uninstall() -> None:
    global _CURRENT
    _CURRENT = None


def current() -> TraceRecorder | None:
    return _CURRENT


@contextmanager
def recording(capacity: int = DEFAULT_CAPACITY):
    """Install a fresh recorder for the duration of the block (restoring
    whatever was installed before): the test/bench-friendly entry point.

    >>> with recording() as rec:
    ...     engine.run()
    >>> export.write(rec, "results/trace_run.json")
    """
    global _CURRENT
    prev = _CURRENT
    rec = TraceRecorder(capacity)
    _CURRENT = rec
    try:
        yield rec
    finally:
        _CURRENT = prev


def span(name: str, cat: str = "", **args):
    """Span against the installed recorder; a shared no-op context when
    none is installed (the instrumented hot paths call this
    unconditionally)."""
    rec = _CURRENT
    if rec is None:
        return _NULL_CONTEXT
    return rec.span(name, cat, **args)


def instant(name: str, cat: str = "", **args) -> None:
    rec = _CURRENT
    if rec is not None:
        rec.instant(name, cat, **args)
