"""Training step: CE loss (+ MoE aux, z-loss), grad, AdamW update.

``make_train_step`` returns a pure function (state, batch) -> (state,
metrics) suitable for jax.jit with in/out shardings from launch/sharding.py.
Microbatching (gradient accumulation) happens inside the step via lax.scan
so the optimizer sees the full global batch while activation memory is
bounded by the microbatch.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import model as model_lib
from repro.models.config import ArchConfig
from repro.optim import AdamWConfig, adamw_init, adamw_update

TrainState = dict  # {"params", "opt", "step"}


def init_state(cfg: ArchConfig, key, opt_cfg: AdamWConfig) -> TrainState:
    params = model_lib.init_params(cfg, key)
    return {"params": params, "opt": adamw_init(params)}


def loss_fn(params, cfg: ArchConfig, batch, use_pallas: bool = False):
    logits, aux = model_lib.forward(params, cfg, batch,
                                    use_pallas=use_pallas)
    # VLM: patch positions carry no next-token target — score text tail only
    v = logits.shape[-1]
    targets = batch["targets"]
    t = targets.shape[1]
    logits = logits[:, -t:]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    ce = nll.mean()
    total = ce
    if cfg.num_experts:
        total = total + 1e-2 * aux["lb_loss"] + 1e-3 * aux["z_loss"]
    return total, {"ce": ce, **{k: v for k, v in aux.items()
                                if k != "expert_load"},
                   "expert_load": aux["expert_load"]}


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig,
                    num_microbatches: int = 1, use_pallas: bool = False):
    grad_fn = jax.value_and_grad(
        functools.partial(loss_fn, cfg=cfg, use_pallas=use_pallas),
        has_aux=True)

    def step(state: TrainState, batch: dict[str, Any]):
        params = state["params"]
        if num_microbatches > 1:
            def micro(carry, mb):
                (loss, aux), g = grad_fn(params, batch=mb)
                acc = jax.tree.map(jnp.add, carry[0], g)
                return (acc, carry[1] + loss), aux

            mbs = jax.tree.map(
                lambda x: x.reshape((num_microbatches,
                                     x.shape[0] // num_microbatches)
                                    + x.shape[1:]), batch)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params)
            (gsum, lsum), auxs = jax.lax.scan(micro, (zero, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / num_microbatches, gsum)
            loss = lsum / num_microbatches
            aux = jax.tree.map(lambda x: x.mean(0) if x.ndim else x, auxs)
        else:
            (loss, aux), grads = grad_fn(params, batch=batch)
        new_params, new_opt, opt_metrics = adamw_update(
            grads, state["opt"], params, opt_cfg)
        metrics = {"loss": loss, **opt_metrics,
                   "ce": aux["ce"]}
        if cfg.num_experts:
            # summed routed-token counts per expert (drives the
            # structure-aware rebalancer, train/expert_balance.py)
            metrics["expert_load"] = aux["expert_load"]
        return {"params": new_params, "opt": new_opt}, metrics

    return step
