"""Streaming table: warm incremental reconvergence vs cold full recompute,
per delta batch, across the three graph families.

Both rows run through the SAME StreamingEngine mutation path and the same
compiled fused superstep, so the comparison isolates exactly the streaming
contribution (dirty-block re-heat + warm values) and not compile noise:

  * ``stream_warm``            — re-heat dirty blocks, warm-start values,
                                 reconverge (`StreamConfig(warm=True)`);
  * ``stream_cold_recompute``  — after the identical mutation, rerun the
                                 whole convergence from ``program.init``
                                 (`StreamConfig(warm=False)`), i.e. what a
                                 batch system does per snapshot.

The paper-claim analogue: warm reconvergence must process strictly fewer
edges and finish faster per batch on the convergence-skewed families.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import algorithms as A
from repro.core import graph as G
from repro.core.engine import EngineConfig
from repro.stream import StreamConfig, StreamingEngine, synthetic_stream


def run(n: int = 20000, num_batches: int = 4, batch_size: int = 200):
    cfg = EngineConfig(t2=1e-8, width=16, block_size=512)
    graphs = {
        "powerlaw": G.powerlaw_graph(n, avg_deg=8, seed=1, weighted=True),
        "coreperiph": G.core_periphery_graph(n, avg_deg=8, seed=1,
                                             chords=1, weighted=True),
        "road": G.uniform_graph(n // 4, deg=4, seed=2, weighted=True),
    }
    rows = []
    for gname, g in graphs.items():
        batches = synthetic_stream(g, num_batches, batch_size, seed=3,
                                   delete_frac=0.2, weighted=True)
        warm = StreamingEngine(g, A.pagerank(), cfg)
        cold = StreamingEngine(g, A.pagerank(), cfg,
                               StreamConfig(warm=False))
        for b in batches:
            warm.ingest(b)
            cold.ingest(b)
        mw, mc = warm.metrics, cold.metrics
        us_w = mw.latency_per_batch_s * 1e6
        us_c = mc.latency_per_batch_s * 1e6
        agree = np.allclose(warm.values, cold.values, rtol=1e-3, atol=1e-5)
        rows.append((
            f"stream/{gname}/pagerank/stream_warm", us_w,
            f"batches={mw.batches};edges={mw.edges_reprocessed};"
            f"iters={mw.iterations};dirty_frac={mw.dirty_frac:.2f};"
            f"upload_frac={mw.upload_frac:.3f};"
            f"appends={mw.appended_blocks};kills={mw.killed_blocks};"
            f"rebuilds={mw.rebuilt_blocks};"
            f"aux_bumped={mw.aux_bumped_blocks};"
            f"plan_rebuilds={mw.plan_rebuilds};"
            f"mean_width={mw.mean_dispatch_width:.1f};"
            f"retired={mw.blocks_retired};"
            f"sub_dirty_frac={mw.subblock_dirty_frac:.2f};"
            f"msd={mw.mean_subblock_dispatch:.2f};agree={agree};"
            f"edge_gain={mc.edges_reprocessed / max(mw.edges_reprocessed, 1):.2f}x;"
            f"speedup_vs_cold={us_c / max(us_w, 1e-9):.2f}x"))
        rows.append((
            f"stream/{gname}/pagerank/stream_cold_recompute", us_c,
            f"batches={mc.batches};edges={mc.edges_reprocessed};"
            f"iters={mc.iterations}"))
        # delta-proportional scaling: tiny batches must reconverge in a
        # NARROW dispatch bucket with a rarer cold admission (the adaptive
        # warm-restart claim) — at this P a 200-edit batch arms most
        # blocks by pigeonhole, so the narrow path only shows on small
        # deltas
        small = StreamingEngine(g, A.pagerank(), cfg)
        for b in synthetic_stream(g, num_batches, batch_size // 20, seed=5,
                                  delete_frac=0.2, weighted=True):
            small.ingest(b)
        ms = small.metrics
        rows.append((
            f"stream/{gname}/pagerank/stream_warm_small",
            ms.latency_per_batch_s * 1e6,
            f"batches={ms.batches};edits={batch_size // 20};"
            f"edges={ms.edges_reprocessed};iters={ms.iterations};"
            f"dirty_frac={ms.dirty_frac:.2f};"
            f"sub_dirty_frac={ms.subblock_dirty_frac:.2f};"
            f"msd={ms.mean_subblock_dispatch:.2f};"
            f"mean_width={ms.mean_dispatch_width:.1f};"
            f"retired={ms.blocks_retired}"))
    return rows


def run_subblock(n: int = 20000, num_batches: int = 4):
    """Hierarchical-partition table: sub-block (S = 8) vs block-granular
    (S = 1) activity tracking over the SAME warm delta stream, at the
    edit sizes where the P-pigeonhole bites — 10-edit batches (endpoints
    land in most blocks, but arm few sub-blocks) and 200-edit batches
    (the block tracker saturates near dirty_frac ~0.7+). Both rows run
    the identical mutation path and compiled superstep; only the
    activity granularity differs, so sub_dirty_frac / msd and the
    speedup isolate exactly the tentpole contribution."""
    g = G.powerlaw_graph(n, avg_deg=8, seed=1, weighted=True)
    base = EngineConfig(t2=1e-8, width=16, block_size=512)
    rows = []
    for edits in (10, 200):
        got = {}
        for sb in (1, 8):
            cfg = dataclasses.replace(base, subblocks=sb)
            se = StreamingEngine(g, A.pagerank(), cfg)
            for b in synthetic_stream(g, num_batches, edits, seed=5,
                                      delete_frac=0.2, weighted=True):
                se.ingest(b)
            got[sb] = (np.asarray(se.values), se.metrics)
        agree = np.allclose(got[1][0], got[8][0], rtol=1e-3, atol=1e-5)
        us = {sb: m.latency_per_batch_s * 1e6 for sb, (_, m) in got.items()}
        for sb, (_, m) in got.items():
            extra = ("" if sb == 1 else
                     f";agree={agree};"
                     f"speedup_vs_block={us[1] / max(us[sb], 1e-9):.2f}x")
            rows.append((
                f"stream/powerlaw/pagerank/stream_warm_small/"
                f"edits{edits}/sub{sb}", us[sb],
                f"batches={m.batches};edits={edits};subblocks={sb};"
                f"edges={m.edges_reprocessed};iters={m.iterations};"
                f"dirty_frac={m.dirty_frac:.2f};"
                f"sub_dirty_frac={m.subblock_dirty_frac:.2f};"
                f"msd={m.mean_subblock_dispatch:.2f};"
                f"sub_retired={m.subblocks_retired};"
                f"mean_width={m.mean_dispatch_width:.1f};"
                f"retired={m.blocks_retired}" + extra))
    return rows
