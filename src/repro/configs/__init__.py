"""Architecture registry: one module per assigned arch (+ graph configs).

``get(name)`` returns the full published config; ``reduced(cfg)`` the
family-preserving smoke-test config; ``input_specs(cfg, shape)`` the
ShapeDtypeStruct stand-ins for the dry-run (no allocation).
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import SHAPES, ArchConfig, ShapeConfig

ARCH_NAMES = [
    "mamba2_2p7b",
    "deepseek_moe_16b",
    "granite_moe_3b_a800m",
    "yi_6b",
    "llama3p2_1b",
    "qwen3_14b",
    "mistral_nemo_12b",
    "phi3_vision_4p2b",
    "hymba_1p5b",
    "whisper_base",
]

_ALIASES = {n.replace("_", "-"): n for n in ARCH_NAMES}
_ALIASES.update({
    "mamba2-2.7b": "mamba2_2p7b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "yi-6b": "yi_6b",
    "llama3.2-1b": "llama3p2_1b",
    "qwen3-14b": "qwen3_14b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "phi-3-vision-4.2b": "phi3_vision_4p2b",
    "hymba-1.5b": "hymba_1p5b",
    "whisper-base": "whisper_base",
})


def get(name: str) -> ArchConfig:
    mod_name = _ALIASES.get(name, name)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> dict[str, ArchConfig]:
    return {n: get(n) for n in ARCH_NAMES}


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Family-preserving small config for CPU smoke tests."""
    kw = dict(
        num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=128, pad_vocab_to=1,
    )
    if cfg.num_experts:
        kw.update(num_experts=4, experts_per_token=2,
                  num_shared_experts=min(cfg.num_shared_experts, 1),
                  moe_d_ff=32)
    if cfg.has_ssm:
        kw.update(ssm_heads=4, ssm_head_dim=8, ssm_state=8, ssm_chunk=32)
    if cfg.is_encdec:
        kw.update(encoder_layers=2)
    if cfg.num_patches:
        kw.update(num_patches=8)
    return dataclasses.replace(cfg, **kw)


def input_specs(cfg: ArchConfig, shape: ShapeConfig, *, concrete=False,
                batch_override: int | None = None,
                seq_override: int | None = None):
    """Model inputs for (cfg, shape): ShapeDtypeStructs by default, tiny
    concrete arrays when concrete=True (smoke tests).

    Returns (batch_dict, kind). decode shapes also need a cache — built via
    cache_specs()."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    b = batch_override or shape.global_batch
    s = seq_override or shape.seq_len
    cdt = jnp.dtype(cfg.dtype)

    def tok(shp):
        if concrete:
            rng = np.random.default_rng(0)
            return jnp.asarray(rng.integers(0, cfg.vocab_size, size=shp,
                                            dtype=np.int32))
        return jax.ShapeDtypeStruct(shp, jnp.int32)

    def emb(shp):
        if concrete:
            rng = np.random.default_rng(1)
            return jnp.asarray(rng.normal(size=shp).astype(np.float32),
                               dtype=cdt)
        return jax.ShapeDtypeStruct(shp, cdt)

    batch: dict = {}
    s_text = s
    if cfg.num_patches:  # vlm: patches occupy the first slots
        s_text = s - cfg.num_patches
        batch["patches"] = emb((b, cfg.num_patches, cfg.d_model))
    if cfg.is_encdec:  # audio stub: encoder frames + decoder tokens
        batch["frames"] = emb((b, s, cfg.d_model))
    if shape.kind == "decode":
        batch["tokens"] = tok((b, 1))
    else:
        batch["tokens"] = tok((b, s_text))
        if shape.kind == "train":
            # targets align with text positions only (patch slots carry no
            # next-token target)
            batch["targets"] = tok((b, s_text))
    return batch


def cache_specs(cfg: ArchConfig, shape: ShapeConfig, *, concrete=False,
                batch_override: int | None = None,
                seq_override: int | None = None):
    """Cache pytree for decode shapes (ShapeDtypeStruct or zeros)."""
    import jax

    from repro.models import model as model_lib

    b = batch_override or shape.global_batch
    s = seq_override or shape.seq_len
    if concrete:
        return model_lib.init_cache(cfg, b, s, enc_seq=s)
    concrete_cache = jax.eval_shape(
        lambda: model_lib.init_cache(cfg, b, s, enc_seq=s))
    return concrete_cache


__all__ = ["ARCH_NAMES", "SHAPES", "get", "all_configs", "reduced",
           "input_specs", "cache_specs"]
