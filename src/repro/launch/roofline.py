"""Roofline analysis from the dry-run's compiled artifacts (deliverable g).

Hardware model (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI. Three terms per (arch x shape x mesh) cell, all computed
from PER-DEVICE quantities of the SPMD-partitioned module (equivalent to the
global/(chips x bw) form in the assignment):

    compute    = HLO_FLOPs_per_dev / peak_FLOPs
    memory     = HLO_bytes_per_dev / HBM_bw
    collective = collective_bytes_per_dev / link_bw

plus MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*D (serve) and the
usefulness ratio MODEL_FLOPS / HLO_FLOPs (catches remat/redundancy waste).

Usage: python -m repro.launch.roofline --in results/dryrun.json [--md out.md]
"""
from __future__ import annotations

import argparse
import json

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
LINK_BW = 50e9  # B/s / link (ICI)

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,  # one token per sequence per step
    "long_500k": 1,
}
SHAPE_BS = {"train_4k": (256, 4096), "prefill_32k": (32, 32768),
            "decode_32k": (128, 1), "long_500k": (1, 1)}
ATTN_CHUNK = 512  # chunked_attention tile (models/attention.py)


def attention_addon(arch: str, shape: str, kind: str) -> tuple[float, float]:
    """Analytic attention (flops, bytes) per DEVICE to add to the HLO
    reconstruction: the chunked-attention inner loops (lax.map over q
    chunks, fori over kv chunks) are `while` bodies that XLA cost analysis
    counts once, and the layer-scan differencing cannot see them. Decode
    attention is loop-free and therefore already counted (addon = 0).

    FLOPs: 2*B*S^2*Hq*Dh for q@k^T + the same for p@v, x0.5 causal,
    x4 for train under full remat (fwd + recompute + 2x bwd).
    Bytes: ideal streaming — q,o once; k,v re-read once per q chunk.
    """
    from repro import configs
    if kind == "decode":
        return 0.0, 0.0
    cfg = configs.get(arch)
    if not cfg.has_attention:
        return 0.0, 0.0
    b, s = SHAPE_BS[shape]
    if s < 2048:  # full_attention path has no loops -> already counted
        return 0.0, 0.0
    hq, dh, hkv = cfg.num_heads, cfg.resolved_head_dim, cfg.num_kv_heads
    mult = 4.0 if kind == "train" else 1.0

    def one(s_q, s_k, causal):
        cf = 0.5 if causal else 1.0
        fl = 4.0 * b * s_q * s_k * hq * dh * cf
        nq = max(s_q // ATTN_CHUNK, 1)
        by = 2.0 * (2 * b * s_q * hq * dh          # q read + o write
                    + nq * cf * 2 * b * s_k * hkv * dh)  # kv re-reads
        return fl, by

    fl, by = one(s, s, causal=True)
    if cfg.is_encdec:
        fe, be = one(s, s, causal=False)  # encoder self-attention
        fc, bc = one(s, s, causal=False)  # cross-attention
        fl, by = fl + fe + fc, by + be + bc
    layers = cfg.num_layers
    return mult * fl * layers, mult * by * layers  # global; caller /chips


def analyze_cell(key: str, r: dict) -> dict | None:
    if r.get("status") != "ok":
        return None
    arch, shape, mesh = key.split("/")
    chips = r["devices"]
    kind = r.get("kind", "train" if shape.startswith("train") else
                 ("decode" if "decode" in shape or "long" in shape
                  else "prefill"))
    if arch == "graph_pagerank":
        attn_fl = attn_by = 0.0
    else:
        attn_fl, attn_by = attention_addon(arch, shape, kind)
    flops_dev = r["flops"] + attn_fl / chips
    bytes_dev = r["bytes_accessed"] + attn_by / chips
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = r["collective_bytes"] / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    tokens = SHAPE_TOKENS.get(shape, 0)
    n_active = r.get("active_params", r.get("params", 0))
    mult = 6 if kind == "train" else 2
    # MODEL_FLOPS = 6/2 * N_active * D plus the inherent attention work
    model_flops = mult * n_active * tokens + \
        (attn_fl / (4.0 if kind == "train" else 1.0)) * \
        (3.0 if kind == "train" else 1.0)  # ideal = no remat recompute
    model_flops_dev = model_flops / chips
    t_ideal = model_flops_dev / PEAK_FLOPS
    t_bound = max(terms.values())
    return {
        "arch": arch, "shape": shape, "mesh": mesh, "chips": chips,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": model_flops,
        "attn_flops_dev": attn_fl / chips,
        "useful_ratio": model_flops_dev / flops_dev if flops_dev else 0.0,
        "roofline_fraction": t_ideal / t_bound if t_bound else 0.0,
        "peak_gb": r.get("peak_bytes", 0) / 1e9,
        "arg_gb": r.get("argument_bytes", 0) / 1e9,
        "temp_gb": r.get("temp_bytes", 0) / 1e9,
    }


def bottleneck_hint(row: dict) -> str:
    d = row["dominant"]
    if d == "compute":
        if row["useful_ratio"] < 0.6:
            return ("compute-bound with low useful ratio: reduce remat "
                    "recompute / fuse the logits matmul")
        return "compute-bound near-useful: raise per-chip utilization (MXU "\
               "block alignment)"
    if d == "memory":
        return ("memory-bound: raise arithmetic intensity — larger "
                "microbatch, fuse elementwise chains, bf16 cache/params")
    return ("collective-bound: re-shard to cut resharding all-gathers, "
            "overlap collectives with compute in the scan body")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", default="results/dryrun.json")
    ap.add_argument("--md", default=None)
    ap.add_argument("--mesh", default="pod16x16",
                    help="mesh to tabulate (roofline table is single-pod)")
    args = ap.parse_args()
    with open(args.inp) as f:
        results = json.load(f)

    rows = []
    skips = []
    for key in sorted(results):
        r = results[key]
        if r.get("status") == "skipped":
            skips.append((key, r["reason"]))
            continue
        if not key.endswith(args.mesh):
            continue
        row = analyze_cell(key, r)
        if row:
            rows.append(row)

    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant "
        "| useful | roofline frac | peak GB/dev | what moves the needle |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.2%} | {r['peak_gb']:.2f} | "
            f"{bottleneck_hint(r)} |")
    table = "\n".join(lines)
    print(table)
    if skips:
        print("\nSkipped cells:")
        for k, reason in skips:
            print(f"  - {k}: {reason}")
    if args.md:
        with open(args.md, "w") as f:
            f.write(table + "\n")
            if skips:
                f.write("\nSkipped cells:\n")
                for k, reason in skips:
                    f.write(f"- `{k}`: {reason}\n")


if __name__ == "__main__":
    main()
