"""Pallas TPU kernel: causal GQA flash attention (prefill hot spot).

Online-softmax tiling (Bq x Bk logits tile resident in VMEM, running
(m, l, acc) carries in scratch), MXU-aligned block shapes (128 multiples).
Causal skipping via pl.when: fully-masked k-blocks are never computed, so
the kernel does S^2/2 work. GQA is expressed in the k/v index_map
(q-head -> kv-head = h // group), so no KV replication is materialized.

Validated in interpret mode against ref.attention; on TPU the scratch
(m, l) vectors would be lane-padded to 128 — kept (Bq, 1) here for clarity.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            block_q: int, block_k: int, num_k: int, causal: bool,
            scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    last_ki = qi * block_q // block_k if causal else num_k - 1
    run = (ki <= last_ki) if causal else (ki >= 0)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale  # (Bq, D)
        k = k_ref[0].astype(jnp.float32)  # (Bk, D)
        v = v_ref[0].astype(jnp.float32)  # (Bk, D)
        logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            cols = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            logits = jnp.where(rows >= cols, logits, _NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]  # (Bq, 1)
        m_cur = jnp.max(logits, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(logits - m_new)  # (Bq, Bk)
        alpha = jnp.exp(m_prev - m_new)  # (Bq, 1)
        l_ref[...] = l_prev * alpha + p.sum(axis=-1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)

    @pl.when(ki == last_ki)
    def _flush():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "block_q", "block_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """q: (B, Hq, S, D); k, v: (B, Hkv, S, D); Hq % Hkv == 0."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0 and s % block_q == 0 and s % block_k == 0
    g = hq // hkv
    scale = 1.0 / d ** 0.5
    num_q, num_k = s // block_q, s // block_k

    qr = q.reshape(b * hq, s, d)
    kr = k.reshape(b * hkv, s, d)
    vr = v.reshape(b * hkv, s, d)

    def kv_map(bh, qi, ki):
        batch, head = bh // hq, (bh % hq) // g
        return (batch * hkv + head, ki, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, block_q=block_q, block_k=block_k,
                          num_k=num_k, causal=causal, scale=scale),
        grid=(b * hq, num_q, num_k),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), kv_map),
            pl.BlockSpec((1, block_k, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, d),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),  # m (running max)
            pltpu.VMEM((block_q, 1), jnp.float32),  # l (running denom)
            pltpu.VMEM((block_q, d), jnp.float32),  # acc (unnormalized out)
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, hq, s, d)
