"""Query-serving demo: mixed user traffic against a live mutating graph.

A powerlaw graph converges once under the streaming engine (PageRank as
the resident host program), then user-style queries — k-source shortest
paths and personalized PageRank — are admitted into lane slots while
synthetic delta batches mutate the graph underneath. Each query pins the
epoch it was submitted against (snapshot isolation: its answer is the
fixpoint of the graph AS OF submission), compatible queries batch into
one fused multi-lane run, and admission is ordered hottest-frontier-first
(paper Eq. 1 activity).

    PYTHONPATH=src python examples/graph_service.py [--n 10000] [--lanes 8]
"""
import argparse

import numpy as np

from repro.core import algorithms as A
from repro.core import graph as G
from repro.core.engine import EngineConfig
from repro.serve import Query, QueryService
from repro.stream import StreamingEngine, synthetic_stream


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=10000)
    ap.add_argument("--lanes", type=int, default=8)
    ap.add_argument("--queries", type=int, default=12)
    ap.add_argument("--batches", type=int, default=2,
                    help="delta batches ingested between query waves")
    ap.add_argument("--batch-size", type=int, default=150)
    args = ap.parse_args()

    g = G.powerlaw_graph(args.n, avg_deg=8, seed=1, weighted=True)
    cfg = EngineConfig(t2=1e-8, width=16, block_size=512)
    se = StreamingEngine(g, A.pagerank(), cfg)
    svc = QueryService(se, max_lanes=args.lanes)
    print(f"host program converged: "
          f"{se.initial_result.metrics.iterations} iterations; "
          f"serving with {args.lanes} lane slots")

    rng = np.random.default_rng(7)
    deltas = synthetic_stream(g, args.batches, args.batch_size, seed=3,
                              delete_frac=0.2, weighted=True)

    # wave 1: a mix of traversals and personalized ranks, pinned to epoch 0
    ids = {}
    for _ in range(args.queries // 2):
        s = int(rng.integers(0, args.n))
        kind = "sssp" if rng.random() < 0.7 else "ppr"
        q = (Query(kind="sssp", source=s) if kind == "sssp"
             else Query(kind="ppr", reset=[s, int(rng.integers(0, args.n))]))
        ids[svc.submit(q)] = q
    # the graph mutates while those queries are still pending ...
    for d in deltas:
        rep = svc.ingest(d)
        print(f"ingest: +{rep.inserts}/-{rep.deletes} edges, "
              f"{rep.dirty_blocks}/{rep.num_blocks} dirty, "
              f"latency {rep.latency_s * 1e3:.0f} ms")
    # ... wave 2 pins the mutated epoch
    for _ in range(args.queries - args.queries // 2):
        s = int(rng.integers(0, args.n))
        ids[svc.submit(Query(kind="sssp", source=s))] = None

    results = svc.run_pending()
    print(f"\n{'qid':>4s} {'kind':>5s} {'epoch':>6s} {'lanes':>6s} "
          f"{'iters':>6s} {'wait ms':>8s} {'run ms':>8s} {'conv':>5s}")
    for r in results:
        print(f"{r.query_id:4d} {r.kind:>5s} {r.epoch:6d} {r.lanes:6d} "
              f"{r.iterations:6d} {r.wait_s * 1e3:8.1f} "
              f"{r.run_s * 1e3:8.1f} {str(r.converged):>5s}")

    m = svc.metrics
    print(f"\n{m.queries} queries in {m.lane_batches} lane batches "
          f"({m.lane_utilization:.0%} lane utilization), "
          f"{m.queries_per_s:.2f} queries/s of engine time; "
          f"{m.epochs_pinned} epochs pinned, "
          f"{se.metrics.snapshots_preserved} snapshot(s) device-copied for "
          f"isolation, {m.stale_answers} answers served from a pinned "
          f"(pre-ingest) epoch")


if __name__ == "__main__":
    main()
