# One function per paper table. Prints ``name,us_per_call,derived`` CSV;
# ``--json`` additionally writes BENCH_runtime.json so PRs can track the
# perf trajectory.
from __future__ import annotations

import argparse
import json
import os
import platform
import sys


def _host_meta(repeats: int) -> dict:
    """Host metadata recorded next to the timing rows: cross-PR comparisons
    on shared/small boxes are only meaningful when the host (and the
    best-of-K protocol) is pinned alongside the numbers."""
    import jax
    return {
        "platform": platform.platform(),
        "python": platform.python_version(),
        "cpu_count": os.cpu_count(),
        "jax": jax.__version__,
        "jax_backend": jax.default_backend(),
        "repeats": repeats,
        "timing": "best-of-%d per row (min us_per_call)" % repeats,
    }


def _merge_best(attempts: list[list[tuple]]) -> list[tuple]:
    """Best-of-K merge: keep each row at its minimum us_per_call (derived
    travels with the winning repeat). Row order follows the first attempt;
    rows that only appear in later repeats are appended."""
    order: list[str] = []
    best: dict[str, tuple] = {}
    for rows in attempts:
        for name, us, derived in rows:
            if name not in best:
                order.append(name)
                best[name] = (name, us, derived)
            elif us < best[name][1]:
                best[name] = (name, us, derived)
    return [best[name] for name in order]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20000,
                    help="graph size for the engine benchmarks")
    ap.add_argument("--suites", default=None,
                    help="comma list: runtime,convergence,io,kernels,"
                         "streaming,stream_subblock,serving,ooc — plus "
                         "serving_smoke, a cheap 2-lane serving subset "
                         "(small n) CI can run without the full matrix")
    ap.add_argument("--only", default=None,
                    help="deprecated alias of --suites")
    ap.add_argument("--lanes", type=int, default=8,
                    help="lane width for the serving suite")
    ap.add_argument("--repeats", type=int, default=1,
                    help="run each suite K times and keep the best "
                         "us_per_call per row — damps the ~±15%% run noise "
                         "of small shared boxes")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_runtime.json ({meta, rows}) next to "
                         "the CSV output")
    ap.add_argument("--trace", action="store_true",
                    help="record host spans + device superstep timelines "
                         "while each suite runs and write "
                         "results/trace_<suite>.json (Chrome-trace JSON; "
                         "open in ui.perfetto.dev or render with "
                         "`python -m repro.obs render <file>`)")
    args = ap.parse_args()

    from benchmarks import (bench_convergence, bench_io, bench_kernels,
                            bench_ooc, bench_runtime, bench_serving,
                            bench_streaming)
    suites = {
        "runtime": lambda: bench_runtime.run(args.n),
        "convergence": lambda: bench_convergence.run(args.n),
        "io": lambda: bench_io.run(args.n),
        "kernels": bench_kernels.run,
        "streaming": lambda: bench_streaming.run(args.n),
        # hierarchical partitions: sub-block vs block activity tracking
        # on small warm batches (the P-pigeonhole comparison)
        "stream_subblock": lambda: bench_streaming.run_subblock(args.n),
        "serving": lambda: bench_serving.run(args.n, lanes=args.lanes),
        # out-of-core tier: residency-budget sweep + warm-restart TTC
        "ooc": lambda: bench_ooc.run(args.n),
        # CI smoke subset: tiny graph, 2 lanes — exercises the whole
        # serve stack (lanes, pinning, churn) without the full matrix
        "serving_smoke": lambda: bench_serving.run(min(args.n, 1500),
                                                   lanes=2),
    }
    default = [k for k in suites if k != "serving_smoke"]
    sel = args.suites or args.only
    pick = sel.split(",") if sel else default
    unknown = [k for k in pick if k not in suites]
    if unknown:
        ap.error(f"unknown suite(s) {unknown}; have {sorted(suites)}")
    if args.json and "io" not in pick:
        # the bytes-loaded trajectory is tracked across PRs: a JSON payload
        # without the I/O table rows silently drops it
        pick.append("io")
    repeats = max(args.repeats, 1)
    if args.trace:
        from repro.obs import export as obs_export
        from repro.obs import trace as obs_trace
        os.makedirs("results", exist_ok=True)
    print("name,us_per_call,derived")
    ok = True
    records = []
    for key in pick:
        attempts: list[list[tuple]] = []
        err = None
        rec = obs_trace.install(obs_trace.TraceRecorder()) \
            if args.trace else None
        try:
            for _ in range(repeats):
                try:
                    attempts.append(suites[key]())
                except ImportError:
                    # a suite that cannot even import is a broken harness,
                    # not a data point — fail loudly instead of emitting an
                    # ERROR row
                    raise
                except Exception as e:  # noqa: BLE001
                    err = e
                    break
        finally:
            if rec is not None:
                obs_trace.uninstall()
                path = obs_export.write(
                    rec, os.path.join("results", f"trace_{key}.json"),
                    meta={"suite": key, "n": args.n, "repeats": repeats})
                print(f"wrote {path} ({len(rec.events)} events)",
                      file=sys.stderr)
        if err is not None and not attempts:
            ok = False
            print(f"{key},-1,ERROR:{err!r}")
            # keep the failure in-band in the JSON payload too: a suite's
            # rows silently vanishing would read as a perf change
            records.append({"suite": key, "name": key, "us_per_call": -1,
                            "derived": f"ERROR:{err!r}"})
            continue
        if err is not None:
            # a repeat died after others succeeded: the merged rows are
            # best-of-fewer than advertised — record that in-band so a
            # later reader of the committed JSON sees it, not just CI
            ok = False
            print(f"{key},-1,ERROR(partial):{err!r}", file=sys.stderr)
            records.append({
                "suite": key, "name": f"{key}/__partial_error",
                "us_per_call": -1,
                "derived": (f"ERROR(best-of-{len(attempts)} only, "
                            f"repeat {len(attempts) + 1} died):{err!r}")})
        for name, us, derived in _merge_best(attempts):
            print(f"{name},{us:.1f},{derived}")
            sys.stdout.flush()
            records.append({"suite": key, "name": name,
                            "us_per_call": round(float(us), 1),
                            "derived": derived})
    if args.json:
        # a partial --suites run must not clobber the other suites' rows:
        # keep any existing row whose suite was not re-run this time, so
        # `--suites kernels --json` appends/refreshes in place
        kept = []
        if os.path.exists("BENCH_runtime.json"):
            try:
                with open("BENCH_runtime.json") as f:
                    prev = json.load(f)
                kept = [r for r in prev.get("rows", [])
                        if r.get("suite") not in pick]
            except (json.JSONDecodeError, OSError):
                kept = []
        with open("BENCH_runtime.json", "w") as f:
            json.dump({"meta": _host_meta(repeats),
                       "rows": kept + records}, f, indent=1)
        print(f"wrote BENCH_runtime.json ({len(kept + records)} rows, "
              f"{len(records)} new)", file=sys.stderr)
    if not ok:
        sys.exit(1)


if __name__ == '__main__':
    main()
