"""int8 error-feedback gradient compression (distributed-optimization trick).

At 1000+ nodes the cross-pod (DCN) gradient all-reduce dominates the step;
8-bit quantization with error feedback cuts those bytes 4x with no
measurable convergence loss (the residual re-enters next step's gradient).
Used by the train driver for the "pod" axis only — ICI all-reduces stay
full-precision.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def int8_encode(x):
    """Symmetric per-tensor int8. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decode(q, scale):
    return q.astype(jnp.float32) * scale


def ef_compress_psum(grads, residuals, axis_name: str):
    """Error-feedback compressed psum over ``axis_name`` (use inside
    shard_map). Returns (mean-reduced grads, new residuals)."""

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, scale = int8_encode(gf)
        deq = int8_decode(q, scale)
        new_r = gf - deq  # what quantization lost, fed back next step
        # int8 payload crosses the wire; scales are tiny f32 psums
        summed = lax.psum(deq, axis_name)
        n = lax.psum(jnp.ones((), jnp.float32), axis_name)
        return (summed / n).astype(g.dtype), new_r

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return treedef.unflatten([o[0] for o in out]), \
        treedef.unflatten([o[1] for o in out])
