"""Attention paths: chunked online-softmax (train/prefill), decode w/ cache.

Layout convention: activations (B, S, H, Dh).

* ``chunked_attention`` is the XLA twin of kernels/flash_attention.py: an
  online-softmax over KV chunks via scan/fori, so the (S x S) logits never
  materialize — required for prefill_32k (a dense 32k^2 x heads logits tensor
  would be ~2 GiB/head) and used for train_4k as well. On TPU the Pallas
  kernel takes over via the use_pallas flag; the dry-run lowers this path.
* ``decode_attention`` does one-token attention against a (possibly
  seq-sharded) KV cache: softmax over a sharded axis is just two sharded
  reductions, which GSPMD turns into the flash-decoding combine.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


def full_attention(q, k, v, causal: bool = True):
    """Reference quadratic path (small S / tests). (B, S, H, D) layout."""
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    scale = d ** -0.5
    qg = q.reshape(b, s, hkv, g, d).astype(jnp.float32)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg,
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(b, s, hq, d).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "chunk_q", "chunk_k",
                                             "dynamic_skip"))
def chunked_attention(q, k, v, causal: bool = True, chunk_q: int = 512,
                      chunk_k: int = 512, dynamic_skip: bool = False):
    """Flash-style attention in pure JAX. q: (B, S, Hq, D), k/v: (B, S, Hkv, D).

    dynamic_skip=True prunes fully-masked KV chunks with a dynamic loop
    bound — 2x less work on the causal half, but the dynamic while_loop is
    NOT reverse-differentiable, so it is for inference paths only. Training
    uses the static bound + masking.
    """
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    if s % chunk_q or s % chunk_k:
        return full_attention(q, k, v, causal)
    nq, nk = s // chunk_q, s // chunk_k
    scale = d ** -0.5
    qc = q.reshape(b, nq, chunk_q, hkv, g, d)
    kc = k.reshape(b, nk, chunk_k, hkv, d)
    vc = v.reshape(b, nk, chunk_k, hkv, d)

    def q_block(qi, q_i):
        # q_i: (B, Cq, Hkv, G, D)
        m0 = jnp.full((b, hkv, g, chunk_q, 1), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, chunk_q, 1), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, chunk_q, d), jnp.float32)

        def kv_step(ki, carry):
            m, lse, acc = carry
            k_i = lax.dynamic_index_in_dim(kc, ki, 1, keepdims=False)
            v_i = lax.dynamic_index_in_dim(vc, ki, 1, keepdims=False)
            logits = jnp.einsum("bqhgd,bkhd->bhgqk",
                                q_i.astype(jnp.float32),
                                k_i.astype(jnp.float32)) * scale
            if causal:
                rows = qi * chunk_q + lax.broadcasted_iota(
                    jnp.int32, (chunk_q, chunk_k), 0)
                cols = ki * chunk_k + lax.broadcasted_iota(
                    jnp.int32, (chunk_q, chunk_k), 1)
                logits = jnp.where((rows >= cols)[None, None, None],
                                   logits, NEG_INF)
            m_new = jnp.maximum(m, logits.max(-1, keepdims=True))
            p = jnp.exp(logits - m_new)
            alpha = jnp.exp(m - m_new)
            lse = lse * alpha + p.sum(-1, keepdims=True)
            acc = acc * alpha + jnp.einsum("bhgqk,bkhd->bhgqd", p,
                                           v_i.astype(jnp.float32))
            return m_new, lse, acc

        # causal + dynamic_skip: only k chunks up to the diagonal (dynamic
        # bound -> while_loop, inference only); else static nk (differentiable)
        if causal and dynamic_skip:
            upper = qi * chunk_q // chunk_k + 1
        else:
            upper = nk
        m, lse, acc = lax.fori_loop(0, upper, kv_step, (m0, l0, a0))
        out = acc / jnp.maximum(lse, 1e-30)
        return jnp.einsum("bhgqd->bqhgd", out)

    outs = lax.map(lambda args: q_block(*args),
                   (jnp.arange(nq), jnp.moveaxis(qc, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1)  # (B, nq, Cq, Hkv, G, D)
    return out.reshape(b, s, hq, d).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, pos):
    """One-step attention. q: (B, 1, Hq, D); caches: (B, Smax, Hkv, D);
    pos: scalar int (tokens [0, pos] are valid, [pos] being the new one)."""
    b, _, hq, d = q.shape
    smax, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    scale = d ** -0.5
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32)
    logits = jnp.einsum("bhgd,bkhd->bhgk", qg,
                        k_cache.astype(jnp.float32)) * scale
    valid = (jnp.arange(smax) <= pos)[None, None, None]
    logits = jnp.where(valid, logits, NEG_INF)
    # sharded-softmax-friendly: max/sum reduce over the (possibly sharded)
    # cache axis; GSPMD inserts the partial-softmax combine
    m = logits.max(-1, keepdims=True)
    p = jnp.exp(logits - m)
    p = p / p.sum(-1, keepdims=True)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, hq, d).astype(q.dtype)


def update_cache(cache_k, cache_v, new_k, new_v, pos):
    """Write new_k/new_v ((B, T, Hkv, D)) at [pos, pos+T)."""
    cache_k = lax.dynamic_update_slice(cache_k, new_k.astype(cache_k.dtype),
                                       (0, pos, 0, 0))
    cache_v = lax.dynamic_update_slice(cache_v, new_v.astype(cache_v.dtype),
                                       (0, pos, 0, 0))
    return cache_k, cache_v
