"""Contract markers: the engine's implicit invariants, machine-readable.

Every contract that used to live only in a docstring gets a decorator
here. Decorating does two things: it stamps the function
(``fn.__contract__``) so readers and tools can see the contract at the
definition site, and it records a :class:`Contract` in a module-level
registry keyed by ``(kind, module, qualname)`` so
:mod:`repro.analysis.tracecheck` can enumerate and *enforce* them.
Factory-built closures (``pagerank().aux_fn`` and friends) re-register on
every factory call — same key, latest target wins — which is exactly
what :func:`discover` exploits: it imports the contract-bearing modules
and instantiates each registered program family so the inner-function
contracts register with live targets.

This module must stay stdlib-only: it is imported by ``core``/``stream``
/``serve``/``ooc`` modules at definition time.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable

# Modules whose import (plus factory instantiation below) populates the
# registry. Order matters only for readability of reports.
CONTRACT_MODULES = (
    "repro.core.algorithms",
    "repro.core.schedule",
    "repro.core.engine",
    "repro.stream.engine",
    "repro.serve.lanes",
    "repro.ooc.prefetch",
    "repro.kernels.block_sweep",
)


@dataclasses.dataclass(frozen=True)
class Contract:
    kind: str  # elementwise | structure_independent | ...
    module: str
    qualname: str
    target: Callable = dataclasses.field(compare=False)
    meta: dict = dataclasses.field(default_factory=dict, compare=False)

    @property
    def key(self) -> tuple[str, str, str]:
        return (self.kind, self.module, self.qualname)

    def __str__(self) -> str:
        return f"{self.kind}: {self.module}:{self.qualname}"


_REGISTRY: dict[tuple[str, str, str], Contract] = {}


def _register(kind: str, fn: Callable, **meta: Any) -> None:
    c = Contract(kind=kind, module=fn.__module__, qualname=fn.__qualname__,
                 target=fn, meta=meta)
    _REGISTRY[c.key] = c


def registry() -> list[Contract]:
    """Current registry contents (whatever has been imported so far)."""
    return sorted(_REGISTRY.values(), key=lambda c: c.key)


def elementwise(fn: Callable | None = None, *,
                shapes: tuple | None = None) -> Callable:
    """``out[i]`` depends only on ``in[i]``: no cross-vertex (axis-0)
    gathers, scatters, reductions, sorts, or scans. The streaming engine
    leans on this to evaluate ``aux_fn`` on just the vertices whose
    degrees moved, and the tiled sweeps lean on it for ``edge_map`` /
    ``sd_delta`` slicing.

    ``shapes`` optionally fixes the probe/trace input shapes per argument
    (a tuple per array argument; the string ``"static"`` marks a plain
    Python scalar argument such as ``n_total``). Without it every
    argument is probed as a rank-1 vector.
    """
    def deco(f: Callable) -> Callable:
        f.__contract__ = "elementwise"
        _register("elementwise", f, shapes=shapes)
        return f
    return deco(fn) if fn is not None else deco


def structure_independent(fn: Callable) -> Callable:
    """Return VALUES are a function of ``n`` and program parameters only
    — never of the edge set. The streaming engine re-applies an
    epoch-time init snapshot to reset vertices instead of re-running init
    on the mutated graph, and serve lanes init over snapshots whose
    degrees are maintained incrementally; both are sound only under this
    contract. (The aux half of a ``VertexProgram.init`` result MAY depend
    on degrees — the contract covers element 0, the values.)"""
    fn.__contract__ = "structure_independent"
    _register("structure_independent", fn)
    return fn


def decision_identical(*, twin: Callable) -> Callable:
    """The decorated implementation makes bitwise the same decisions as
    ``twin`` (same picks, same order, same tie-breaks). This is the
    contract the out-of-core tier's bitwise guarantee hangs on: one host
    ``twin`` call predicts exactly what the device implementation will
    schedule."""
    def deco(fn: Callable) -> Callable:
        fn.__contract__ = "decision_identical"
        _register("decision_identical", fn, twin=twin)
        return fn
    return deco


def one_executable_per(*key: str) -> Callable:
    """The decorated compiled-function getter returns ONE cached
    executable per distinct ``key`` tuple (e.g. ``("chunk", "width")``):
    repeat calls with the same key must return the identical object and
    must not grow the cache — per-call recompiles are the regression this
    guards against."""
    def deco(fn: Callable) -> Callable:
        fn.__contract__ = "one_executable_per"
        _register("one_executable_per", fn, key=key)
        return fn
    return deco


def deterministic(fn: Callable) -> Callable:
    """Pure function of its inputs: stable orders, id tie-breaks, no
    clocks, no unseeded randomness. Marks the schedule-affecting ranking
    helpers; the lint layer's nondeterminism rule applies to every module
    containing one of these."""
    fn.__contract__ = "deterministic"
    _register("deterministic", fn)
    return fn


def discover() -> list[Contract]:
    """Import every contract-bearing module, instantiate the registered
    program factories so inner-function contracts register with live
    targets, and return the full registry."""
    for mod in CONTRACT_MODULES:
        importlib.import_module(mod)
    alg = importlib.import_module("repro.core.algorithms")
    for factory in alg.REGISTRY.values():
        factory()
    for factory in alg.LANE_FAMILIES.values():
        factory()
    return registry()
