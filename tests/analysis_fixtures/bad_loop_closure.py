"""RA003 fixture: a jitted function closes over a loop-varying Python
scalar — one executable compiles per distinct value (the i2 recompile
hazard)."""
import jax


def run_batches(values, batches):
    results = []
    for i2 in (4, 8, 16, 32):
        def superstep(v):
            # i2 is baked into the trace: 4 compiles for 4 cadences
            return v * i2

        results.append(jax.jit(superstep)(values))
    return results
