"""Shared test fixtures + numpy oracles.

NOTE: no XLA_FLAGS here — tests run with the real single CPU device;
distributed tests spawn subprocesses that set their own device count.
"""
import numpy as np
import pytest

from repro.core import graph as G


def pr_oracle(g, iters=500, d=0.85):
    r = np.full(g.n, 1.0 / g.n, dtype=np.float64)
    outdeg = np.maximum(g.out_deg, 1).astype(np.float64)
    s, dst, _ = G.edges_of(g)
    for _ in range(iters):
        agg = np.zeros(g.n)
        np.add.at(agg, dst, r[s] / outdeg[s])
        r = (1 - d) / g.n + d * agg
    return r


def bellman_ford_oracle(g, src=0, unit=False):
    s, d, w = G.edges_of(g)
    if unit:
        w = np.ones_like(w)
    dist = np.full(g.n, 1e18)
    dist[src] = 0.0
    for _ in range(g.n):
        nd = dist.copy()
        np.minimum.at(nd, d, dist[s] + w)
        if np.array_equal(nd, dist):
            break
        dist = nd
    return dist


def ppr_oracle(g, reset_ids, d=0.85, iters=500):
    """Personalized PageRank power iteration: x = (1-d) r + d A x with r
    uniform over ``reset_ids``; dangling mass vanishes (aux = max(out, 1)),
    matching the engine's pagerank semantics."""
    r = np.zeros(g.n)
    r[np.asarray(reset_ids, dtype=np.int64)] = 1.0 / len(reset_ids)
    s, dst, _ = G.edges_of(g)
    outdeg = np.maximum(g.out_deg, 1).astype(np.float64)
    x = r.copy()
    for _ in range(iters):
        agg = np.zeros(g.n)
        np.add.at(agg, dst, x[s] / outdeg[s])
        x = (1 - d) * r + d * agg
    return x


def cc_oracle(g):
    """Union-find component roots on the symmetrized graph."""
    parent = list(range(g.n))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    s, d, _ = G.edges_of(g)
    for a, b in zip(s, d):
        ra, rb = find(int(a)), find(int(b))
        if ra != rb:
            parent[ra] = rb
    return np.array([find(i) for i in range(g.n)])


@pytest.fixture(scope="session")
def powerlaw_small():
    return G.powerlaw_graph(2000, avg_deg=6, seed=1)


@pytest.fixture(scope="session")
def core_periphery_small():
    return G.core_periphery_graph(5000, avg_deg=8, seed=1, chords=1)
