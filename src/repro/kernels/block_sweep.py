"""Pallas TPU kernel family: the fused VMEM-resident block sweep.

One ``pallas_call`` per scheduled block fuses the whole per-block update —
edge-tile gather → ``edge_map`` → segmented combine → ``apply`` — that the
dense engine expresses as a ``fori_loop`` of HLO gathers and serial
scatters (``make_tiled_processor`` / ``make_lane_processor``). The grid
walks the block's tile rows (scalar-prefetched ``[t0, tile_cnt, base]``
drives a dynamic index map into the SHARED tile arrays, so every block
reuses one executable); each step streams one ``(1, TILE)`` edge tile
HBM→VMEM while the ``(1, C)`` — or ``(C, L)`` lane — accumulator stays
VMEM-resident across the whole loop (the accumulator pattern proven in
``spmv.py``). ``apply`` runs in-kernel at the last grid step, so HBM
traffic per block is exactly E edge reads + C·L value writes: the paper's
cache-block residency claim, realized literally.

Combine families:

- ``sum`` — one-hot matmul on the MXU, ``(1, E_t) @ (E_t, C)`` single-lane
  or ``(C, E_t) @ (E_t, L)`` lane-batched (the PPR scatter fix).
- ``min`` / ``max`` — masked select against a broadcast one-hot then a
  tree reduce over the tile axis; exact (order-independent), so SSSP/BFS
  stay bitwise.

Sub-block activity (``subblocks = S > 1``) is honored INSIDE the kernel:
``sub_act`` rides the scalar-prefetch vector and a tile whose ``cov`` row
misses every live sub-range leaves the accumulator untouched — the same
identity branch the dense ``lax.cond`` takes, so parity is by
construction, not by rounding luck.

Everything here is bitwise-identical to the dense reference on this
backend (property-tested in ``tests/test_block_sweep.py``); the dense
path remains the oracle. ``interpret=True`` runs the same kernels under
the Pallas interpreter on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.analysis.contracts import one_executable_per

# one sweep callable per (program, tile geometry, mode): the engines build
# their processors once per epoch, and repeated builds (prewarm, contract
# probes) must not mint fresh closures or the jit caches downstream refill
_BUILDER_CACHE: dict = {}
_BUILDER_CACHE_CAP = 32


# -- per-tile segmented min/max (the _combine_local counterpart) -------------
def _seg_kernel(msg_ref, dst_ref, out_ref, *, tile_e: int, block_c: int,
                combine: str, identity: float):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, identity)

    msg = msg_ref[...].reshape(tile_e)
    dst = dst_ref[...]
    cols = jax.lax.broadcasted_iota(jnp.int32, (tile_e, block_c), 1)
    onehot = dst.reshape(tile_e, 1) == cols
    sel = jnp.where(onehot, msg.reshape(tile_e, 1), identity)
    if combine == "min":
        out_ref[...] = jnp.minimum(out_ref[...],
                                   sel.min(axis=0).reshape(1, block_c))
    else:
        out_ref[...] = jnp.maximum(out_ref[...],
                                   sel.max(axis=0).reshape(1, block_c))


@functools.partial(jax.jit, static_argnames=("block_size", "identity",
                                             "combine", "tile_e",
                                             "interpret"))
def _edge_block_select(msg, dst, block_size: int, identity: float,
                       combine: str, tile_e: int = 512,
                       interpret: bool = True):
    """Segmented min/max of ``msg`` into ``block_size`` slots: the scatter
    ``full(identity).at[dst].min(msg)`` as a masked select + tree reduce
    (exact, so bitwise vs the scatter). Pad messages are ``identity`` so
    slot 0 is unaffected."""
    e = msg.shape[0]
    pad = (-e) % tile_e
    if pad:
        msg = jnp.pad(msg, (0, pad), constant_values=identity)
        dst = jnp.pad(dst, (0, pad))
    e_pad = e + pad
    out = pl.pallas_call(
        functools.partial(_seg_kernel, tile_e=tile_e, block_c=block_size,
                          combine=combine, identity=identity),
        grid=(e_pad // tile_e,),
        in_specs=[
            pl.BlockSpec((1, tile_e), lambda i: (0, i)),
            pl.BlockSpec((1, tile_e), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, block_size), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, block_size), jnp.float32),
        interpret=interpret,
    )(msg.reshape(1, e_pad).astype(jnp.float32),
      dst.reshape(1, e_pad).astype(jnp.int32))
    return out.reshape(block_size).astype(msg.dtype)


def edge_block_min(msg, dst, block_size: int, identity: float,
                   tile_e: int = 512, interpret: bool = True):
    return _edge_block_select(msg, dst, block_size, identity, "min",
                              tile_e=tile_e, interpret=interpret)


def edge_block_max(msg, dst, block_size: int, identity: float,
                   tile_e: int = 512, interpret: bool = True):
    return _edge_block_select(msg, dst, block_size, identity, "max",
                              tile_e=tile_e, interpret=interpret)


# -- the fused block sweep ---------------------------------------------------
def _sweep_kernel(s_ref, *refs, edge_map, apply_fn, combine: str,
                  identity: float, tile: int, c: int, n_total: int,
                  t_max: int, lanes: bool, masked: bool):
    """Grid = the block's tile rows. s_ref (scalar prefetch, SMEM) is
    ``[t0, tile_cnt, base]`` (+ ``sub_act`` as int32 when masked); tile
    refs are ``(1, tile)`` VMEM blocks selected by the dynamic index map;
    values/aux (and vconst for lanes) are whole-array ANY refs (the gather
    needs random access across block boundaries); agg/new are VMEM-
    resident accumulator outputs revisited by every step."""
    if masked:
        src_ref, dstl_ref, w_ref, valid_ref, cov_ref, *rest = refs
    else:
        src_ref, dstl_ref, w_ref, valid_ref, *rest = refs
        cov_ref = None
    if lanes:
        values_ref, aux_ref, vconst_ref, agg_ref, new_ref = rest
    else:
        values_ref, aux_ref, agg_ref, new_ref = rest
        vconst_ref = None

    t = pl.program_id(0)
    s = s_ref[...]

    @pl.when(t == 0)
    def _init():
        agg_ref[...] = jnp.full_like(agg_ref, identity)

    active = t < s[1]
    if masked:
        # the dense path's lax.cond identity branch, in-kernel: a tile
        # whose covered sub-ranges are all masked must leave agg untouched
        active = active & (cov_ref[0, :] & (s[3:] > 0)).any()

    @pl.when(active)
    def _accumulate():
        vals = values_ref[...]
        auxv = aux_ref[...]
        e_src = src_ref[0, :]
        msg = edge_map(vals[e_src], auxv[e_src], w_ref[0, :])
        valid = valid_ref[0, :]
        msg = jnp.where(valid[:, None] if lanes else valid, msg, identity)
        cols = jax.lax.broadcasted_iota(jnp.int32, (tile, c), 1)
        onehot = dstl_ref[0, :].reshape(tile, 1) == cols
        if combine == "sum":
            ohf = onehot.astype(jnp.float32)
            if lanes:
                # one (1, E_t) @ (E_t, C) matmul per lane (L is static at
                # trace time). A single (C, E_t) @ (E_t, L) gemm is the
                # higher-arithmetic-intensity MXU form, but its reduction
                # blocking reassociates the sum (~1e-7 drift) — the gemv
                # shape accumulates in edge order, which keeps the lane
                # path bitwise vs the scatter reference
                agg_ref[...] += jnp.stack(
                    [jnp.dot(msg[:, lane].reshape(1, tile), ohf,
                             preferred_element_type=jnp.float32).reshape(c)
                     for lane in range(msg.shape[1])], axis=1)
            else:
                agg_ref[...] += jnp.dot(msg.reshape(1, tile), ohf,
                                        preferred_element_type=jnp.float32)
        else:
            mer = jnp.minimum if combine == "min" else jnp.maximum
            if lanes:
                sel = jnp.where(onehot[:, :, None], msg[:, None, :],
                                identity)
            else:
                sel = jnp.where(onehot, msg.reshape(tile, 1), identity)
            red = sel.min(axis=0) if combine == "min" else sel.max(axis=0)
            agg_ref[...] = mer(agg_ref[...],
                               red if lanes else red.reshape(1, c))

    @pl.when(t == t_max - 1)
    def _apply():
        base = s[2]
        if lanes:
            old = values_ref[pl.ds(base, c), :]
            vc = vconst_ref[pl.ds(base, c), :]
            new_ref[...] = apply_fn(old, agg_ref[...], vc, n_total)
        else:
            old = values_ref[pl.ds(base, c)]
            new = apply_fn(old, agg_ref[...].reshape(c), n_total)
            new_ref[...] = new.reshape(1, c)


def _cache_put(key, sweep):
    if len(_BUILDER_CACHE) >= _BUILDER_CACHE_CAP:
        _BUILDER_CACHE.pop(next(iter(_BUILDER_CACHE)))
    _BUILDER_CACHE[key] = sweep


@one_executable_per("program", "tile geometry", "subblocks", "lanes")
def make_block_sweep(program, tile_start, tile_cnt, *, n_tiles: int,
                     tile_w: int, block_size: int, n_total: int,
                     subblocks: int = 1, lanes: bool = False,
                     interpret: bool = True):
    """Build the fused sweep for one program over one tile geometry.

    Returns ``sweep(ed, values, row[, sub_act])`` — or
    ``sweep(ed, values, vconst, row[, sub_act])`` with ``lanes=True`` —
    producing the block's post-``apply`` ``(C,)`` / ``(C, L)`` values
    (pre vmask/keep masking, exactly what the dense processors compute
    before their delta tails). Memoized per (program, geometry, mode) so
    repeated processor builds reuse one closure and the downstream jit
    caches stay warm.
    """
    ts = np.asarray(tile_start, dtype=np.int32)
    tc = np.asarray(tile_cnt, dtype=np.int32)
    key = (program, ts.tobytes(), tc.tobytes(), int(n_tiles), int(tile_w),
           int(block_size), int(n_total), int(subblocks), bool(lanes),
           bool(interpret))
    cached = _BUILDER_CACHE.get(key)
    if cached is not None:
        return cached

    c = block_size
    tile = tile_w
    t_max = int(tc.max()) if tc.size else 0
    masked = subblocks > 1
    is_sum = program.combine == "sum"
    t0_d = jnp.asarray(ts)
    tc_d = jnp.asarray(tc)

    if t_max == 0 or n_tiles == 0:
        # no tiles anywhere: the dense fori is a no-op, only apply runs
        if lanes:
            def sweep(ed, values, vconst, row, sub_act=None):
                nl = values.shape[1]
                base = row * c
                old = lax.dynamic_slice(values, (base, 0), (c, nl))
                vc = lax.dynamic_slice(vconst, (base, 0), (c, nl))
                agg0 = (jnp.zeros((c, nl), jnp.float32) if is_sum
                        else jnp.full((c, nl), program.identity))
                return program.apply(old, agg0, vc, n_total)
        else:
            def sweep(ed, values, row, sub_act=None):
                base = row * c
                old = lax.dynamic_slice(values, (base,), (c,))
                agg0 = (jnp.zeros(c, jnp.float32) if is_sum
                        else jnp.full(c, program.identity))
                return program.apply(old, agg0, n_total)
        _cache_put(key, sweep)
        return sweep

    kern = functools.partial(
        _sweep_kernel, edge_map=program.edge_map, apply_fn=program.apply,
        combine=program.combine, identity=float(program.identity),
        tile=tile, c=c, n_total=n_total, t_max=t_max, lanes=lanes,
        masked=masked)

    def _tile_map(t, s):
        # clamped so inactive trailing steps (t >= tile_cnt) prefetch a
        # real row; @pl.when masks their contribution
        return (jnp.minimum(s[0] + t, n_tiles - 1), 0)

    tile_spec = pl.BlockSpec((1, tile), _tile_map)
    any_spec = pl.BlockSpec(memory_space=pltpu.ANY)

    def call(scalars, operands, nl):
        in_specs = [tile_spec] * 4
        if masked:
            in_specs.append(pl.BlockSpec((1, subblocks), _tile_map))
        in_specs += [any_spec, any_spec]  # values, aux
        if lanes:
            in_specs.append(any_spec)  # vconst
            out_shape = jax.ShapeDtypeStruct((c, nl), jnp.float32)
            out_spec = pl.BlockSpec((c, nl), lambda t, s: (0, 0))
        else:
            out_shape = jax.ShapeDtypeStruct((1, c), jnp.float32)
            out_spec = pl.BlockSpec((1, c), lambda t, s: (0, 0))
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1, grid=(t_max,), in_specs=in_specs,
            out_specs=[out_spec, out_spec])
        _, new = pl.pallas_call(
            kern, grid_spec=grid_spec, out_shape=[out_shape, out_shape],
            interpret=interpret)(scalars, *operands)
        return new

    if lanes:
        def sweep(ed, values, vconst, row, sub_act=None):
            nl = values.shape[1]
            scal = jnp.stack([t0_d[row], tc_d[row],
                              row * c]).astype(jnp.int32)
            if masked:
                scal = jnp.concatenate([scal, sub_act.astype(jnp.int32)])
                operands = (ed.src, ed.dstl, ed.w, ed.valid, ed.cov,
                            values, ed.aux, vconst)
            else:
                operands = (ed.src, ed.dstl, ed.w, ed.valid,
                            values, ed.aux, vconst)
            return call(scal, operands, nl)
    else:
        def sweep(ed, values, row, sub_act=None):
            scal = jnp.stack([t0_d[row], tc_d[row],
                              row * c]).astype(jnp.int32)
            if masked:
                scal = jnp.concatenate([scal, sub_act.astype(jnp.int32)])
                operands = (ed.src, ed.dstl, ed.w, ed.valid, ed.cov,
                            values, ed.aux)
            else:
                operands = (ed.src, ed.dstl, ed.w, ed.valid,
                            values, ed.aux)
            return call(scal, operands, 1).reshape(c)

    _cache_put(key, sweep)
    return sweep
