"""jit'd dispatch wrappers for the Pallas kernels.

``interpret`` auto-detects: compiled Mosaic lowering on TPU, Python
interpretation elsewhere (CPU validation). Every op has a pure-jnp oracle in
ref.py; tests sweep shapes/dtypes and assert allclose.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import block_sweep as _bs
from repro.kernels import flash_attention as _fa
from repro.kernels import ref
from repro.kernels import spmv as _spmv
from repro.kernels import ssd_scan as _ssd


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def edge_block_sum(msg: jnp.ndarray, dst: jnp.ndarray,
                   block_size: int) -> jnp.ndarray:
    return _spmv.edge_block_sum(msg, dst, block_size,
                                interpret=_interpret())


def edge_block_min(msg: jnp.ndarray, dst: jnp.ndarray, block_size: int,
                   identity: float) -> jnp.ndarray:
    return _bs.edge_block_min(msg, dst, block_size, identity,
                              interpret=_interpret())


def edge_block_max(msg: jnp.ndarray, dst: jnp.ndarray, block_size: int,
                   identity: float) -> jnp.ndarray:
    return _bs.edge_block_max(msg, dst, block_size, identity,
                              interpret=_interpret())


def make_block_sweep(program, store, block_size: int, n_total: int, *,
                     subblocks: int = 1, lanes: bool = False):
    """Build the fused per-block sweep (gather→edge_map→combine→apply in
    one pallas_call) over ``store``'s tile geometry. See
    :mod:`repro.kernels.block_sweep`."""
    return _bs.make_block_sweep(
        program, store.tile_start, store.tile_cnt,
        n_tiles=int(store.src.shape[0]), tile_w=int(store.src.shape[1]),
        block_size=block_size, n_total=n_total, subblocks=subblocks,
        lanes=lanes, interpret=_interpret())


def flash_attention(q, k, v, causal: bool = True, block_q: int = 128,
                    block_k: int = 128):
    return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=_interpret())


def attention(q, k, v, causal: bool = True, use_pallas: bool = False):
    """Model-facing attention entry point: Pallas kernel on TPU / by flag,
    reference math elsewhere (the dry-run lowers the XLA path)."""
    if use_pallas:
        return flash_attention(q, k, v, causal=causal)
    return ref.attention(q, k, v, causal=causal)


def ssd_intra_chunk(c, b, u, ld):
    return _ssd.ssd_intra_chunk(c, b, u, ld, interpret=_interpret())
