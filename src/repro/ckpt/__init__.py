from repro.ckpt.manager import CheckpointManager, reshard

__all__ = ["CheckpointManager", "reshard"]
