"""Edge deltas (:class:`DeltaBatch`) + a reproducible synthetic stream.

The stream generator models the regimes the paper's big-data motivation
names (graphs "incrementally described" over time): preferential-attachment
inserts (the rich-get-richer growth that KEEPS the degree distribution
power-law as the graph evolves), uniform random deletes (unfollow /
link-rot churn), and bursty hotspots (a celebrity moment: a batch
concentrates its inserts onto one vertex, re-heating a cold region).

Semantics — fixed vertex set, edge multiset deltas, applied
deletes-then-inserts:

  * an insert appends one (src, dst, w) edge copy (parallel copies allowed,
    matching ``from_edges``);
  * a delete removes ALL live parallel copies of its (src, dst) pair —
    pair-granular deletion keeps the semantics identical between the
    incremental path and a cold ``from_edges`` rebuild, with no ambiguity
    about WHICH copy dies.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import Graph, edges_of


def _ids(a) -> np.ndarray:
    return np.asarray(a, dtype=np.int64).reshape(-1)


@dataclasses.dataclass(frozen=True)
class DeltaBatch:
    """One atomic mutation step: deletes applied first, then inserts."""

    ins_src: np.ndarray  # (I,) int64
    ins_dst: np.ndarray  # (I,) int64
    ins_w: np.ndarray  # (I,) float32
    del_src: np.ndarray  # (D,) int64 — pair deletes (all parallel copies)
    del_dst: np.ndarray  # (D,) int64

    def __post_init__(self):
        for name in ("ins_src", "ins_dst", "del_src", "del_dst"):
            object.__setattr__(self, name, _ids(getattr(self, name)))
        object.__setattr__(
            self, "ins_w",
            np.asarray(self.ins_w, dtype=np.float32).reshape(-1))
        if not (self.ins_src.size == self.ins_dst.size == self.ins_w.size):
            raise ValueError("insert arrays must have equal length")
        if self.del_src.size != self.del_dst.size:
            raise ValueError("delete arrays must have equal length")

    @property
    def n_inserts(self) -> int:
        return int(self.ins_src.size)

    @property
    def n_deletes(self) -> int:
        return int(self.del_src.size)

    @classmethod
    def empty(cls) -> "DeltaBatch":
        z = np.empty(0, dtype=np.int64)
        return cls(ins_src=z, ins_dst=z, ins_w=np.empty(0, np.float32),
                   del_src=z, del_dst=z)

    @classmethod
    def of(cls, ins=(), dels=(), weighted: bool = False,
           seed: int = 0) -> "DeltaBatch":
        """Convenience constructor from [(u, v), ...] / [(u, v, w), ...]."""
        rng = np.random.default_rng(seed)
        isrc, idst, iw = [], [], []
        for e in ins:
            isrc.append(e[0])
            idst.append(e[1])
            iw.append(e[2] if len(e) > 2
                      else (rng.uniform(0.1, 1.0) if weighted else 1.0))
        dsrc = [e[0] for e in dels]
        ddst = [e[1] for e in dels]
        return cls(ins_src=np.array(isrc), ins_dst=np.array(idst),
                   ins_w=np.array(iw, dtype=np.float32),
                   del_src=np.array(dsrc), del_dst=np.array(ddst))


def synthetic_stream(g: Graph, num_batches: int, batch_size: int,
                     seed: int = 0, delete_frac: float = 0.2,
                     hotspot_prob: float = 0.25, hotspot_frac: float = 0.5,
                     weighted: bool = False) -> list[DeltaBatch]:
    """Reproducible delta stream over ``g``'s live edge multiset.

    Each batch carries ~``batch_size`` operations: ``delete_frac`` of them
    pair-deletes sampled from the CURRENT live edges (so deletes always hit
    something), the rest preferential-attachment inserts (dst ~ in_deg + 1,
    src uniform). With probability ``hotspot_prob`` a batch is a burst:
    ``hotspot_frac`` of its inserts all land on one random hotspot vertex.
    The generator tracks the live multiset across batches (delete-all-pairs
    semantics, exactly like the engine), so the same seed always produces
    the same mutated graph trajectory.
    """
    if g.n < 2:
        raise ValueError("stream needs at least 2 vertices")
    rng = np.random.default_rng(seed)
    src, dst, w = edges_of(g)
    src = src.copy()
    dst = dst.copy()
    w = w.astype(np.float32).copy()
    in_deg = np.bincount(dst, minlength=g.n).astype(np.float64)
    n = g.n
    batches: list[DeltaBatch] = []

    for _ in range(num_batches):
        n_del = min(int(round(batch_size * delete_frac)), src.size)
        n_ins = max(batch_size - n_del, 0)

        # deletes: distinct pairs drawn from the live multiset
        if n_del and src.size:
            pick = rng.choice(src.size, size=n_del, replace=False)
            dkeys = np.unique(src[pick] * n + dst[pick])
            dsrc, ddst = dkeys // n, dkeys % n
        else:
            dsrc = ddst = np.empty(0, dtype=np.int64)

        # inserts: preferential attachment + optional hotspot burst
        p = in_deg + 1.0
        p /= p.sum()
        idst = rng.choice(n, size=n_ins, p=p)
        isrc = rng.integers(0, n, size=n_ins)
        if n_ins and rng.random() < hotspot_prob:
            hot = int(rng.integers(0, n))
            burst = rng.random(n_ins) < hotspot_frac
            idst[burst] = hot
        iw = (rng.uniform(0.1, 1.0, size=n_ins).astype(np.float32)
              if weighted else np.ones(n_ins, dtype=np.float32))

        batches.append(DeltaBatch(ins_src=isrc, ins_dst=idst, ins_w=iw,
                                  del_src=dsrc, del_dst=ddst))

        # advance the live multiset: deletes first, then inserts
        if dsrc.size:
            keys = src * n + dst
            gone = np.isin(keys, dsrc * n + ddst)
            np.subtract.at(in_deg, dst[gone], 1.0)
            src, dst, w = src[~gone], dst[~gone], w[~gone]
        if n_ins:
            src = np.concatenate([src, isrc])
            dst = np.concatenate([dst, idst])
            w = np.concatenate([w, iw])
            np.add.at(in_deg, idst, 1.0)

    return batches


def apply_to_coo(src: np.ndarray, dst: np.ndarray, w: np.ndarray, n: int,
                 batch: DeltaBatch) -> tuple[np.ndarray, np.ndarray,
                                             np.ndarray]:
    """Reference (non-incremental) application of a batch to a COO edge
    list: the oracle the incremental path is tested against."""
    if batch.n_deletes:
        keys = src * n + dst
        gone = np.isin(keys, batch.del_src * n + batch.del_dst)
        src, dst, w = src[~gone], dst[~gone], w[~gone]
    if batch.n_inserts:
        src = np.concatenate([src, batch.ins_src])
        dst = np.concatenate([dst, batch.ins_dst])
        w = np.concatenate([w.astype(np.float32), batch.ins_w])
    return src, dst, w
