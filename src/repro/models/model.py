"""Unified model assembly for all 10 assigned architectures.

One decoder stack parameterized by ArchConfig covers dense / MoE / SSM /
hybrid / VLM-stub; whisper adds an encoder stack + cross-attention. Layer
params are stacked (L, ...) and consumed by lax.scan (remat-wrapped in the
train path); caches are stacked the same way and threaded through the scan.

Entry points:
    init_params(cfg, key)                      -> param pytree (f32 masters)
    forward(params, cfg, batch)                -> logits (train/prefill math)
    init_cache(cfg, batch, max_seq)            -> cache pytree
    prefill(params, cfg, batch, cache)         -> (last logits, cache)
    decode_step(params, cfg, token, cache)     -> (logits, cache)
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.config import ArchConfig
from repro.models.layers import (apply_rope, dense_init, embed_init,
                                 rms_norm, swiglu)

Params = dict

# Launcher-installed activation sharding for attention (see
# set_attention_sharding): (batch_axes tuple, model_axis name) or None.
_ATTN_SHARDING: list = [None]


def set_attention_sharding(batch_axes, model_axis):
    """Install (or clear, with None) the attention activation sharding used
    when cfg.shard_attn is on. Called by the launch layer per mesh."""
    _ATTN_SHARDING[0] = ((tuple(batch_axes), model_axis)
                         if model_axis else None)


def _constrain_bshd(x, cfg):
    if not cfg.shard_attn or _ATTN_SHARDING[0] is None:
        return x
    from jax.sharding import PartitionSpec as P
    batch_axes, model_axis = _ATTN_SHARDING[0]
    spec = P(batch_axes or None, None, model_axis, None)
    return jax.lax.with_sharding_constraint(x, spec)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------
def _attn_params(key, cfg: ArchConfig, d: int):
    dh = cfg.resolved_head_dim
    hq, hkv = cfg.q_heads_eff, cfg.kv_heads_eff
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (d, hq * dh)),
        "wk": dense_init(ks[1], (d, hkv * dh)),
        "wv": dense_init(ks[2], (d, hkv * dh)),
        # Residual-branch output projection starts at zero (skip-init): each
        # block is the identity at step 0, so the residual stream carries no
        # init-time drift. Near-uniform attention at random init otherwise
        # emits a near-constant vector per layer whose accumulated mean
        # component swamps token-dependent signal (and e.g. biases MoE
        # routing) before training has moved any weights.
        "wo": jnp.zeros((hq * dh, d), jnp.float32),
    }
    # EXACT padding: zero the padded head slices (wq/wk/wv columns, wo
    # rows). Padded q heads then see uniform attention over zero values ->
    # zero output -> zero wo contribution, and all their grads vanish.
    if hq > cfg.num_heads:
        real = cfg.num_heads * dh
        p["wq"] = p["wq"].at[:, real:].set(0.0)
        p["wo"] = p["wo"].at[real:, :].set(0.0)
    if hkv > cfg.num_kv_heads:
        real = cfg.num_kv_heads * dh
        p["wk"] = p["wk"].at[:, real:].set(0.0)
        p["wv"] = p["wv"].at[:, real:].set(0.0)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), jnp.float32)
        p["k_norm"] = jnp.ones((dh,), jnp.float32)
    return p


def _ssm_params(key, cfg: ArchConfig, d: int):
    h, p_, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    din = h * p_
    conv_ch = din + 2 * n
    ks = jax.random.split(key, 8)
    return {
        "in_x": dense_init(ks[0], (d, din)),
        "in_z": dense_init(ks[1], (d, din)),
        "in_b": dense_init(ks[2], (d, n)),
        "in_c": dense_init(ks[3], (d, n)),
        "in_dt": dense_init(ks[4], (d, h)),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[5], (h,), jnp.float32,
                                       jnp.log(1e-3), jnp.log(1e-1))))),
        "a_log": jnp.log(jax.random.uniform(ks[6], (h,), jnp.float32,
                                            1.0, 16.0)),
        "d_skip": jnp.ones((h,), jnp.float32),
        "conv_w": dense_init(ks[7], (cfg.ssm_conv_width, conv_ch),
                             scale=cfg.ssm_conv_width ** -0.5),
        "ssm_norm": jnp.ones((din,), jnp.float32),
        "out": dense_init(ks[7], (din, d), scale=din ** -0.5),
    }


def _layer_params(key, cfg: ArchConfig, cross: bool = False):
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    p: Params = {"ln1": jnp.ones((d,), jnp.float32)}
    if cfg.has_attention:
        p["attn"] = _attn_params(ks[0], cfg, d)
    if cfg.has_ssm:
        p["ssm"] = _ssm_params(ks[1], cfg, d)
    if cross:
        p["ln_cross"] = jnp.ones((d,), jnp.float32)
        p["cross"] = _attn_params(ks[2], cfg, d)
    if cfg.num_experts:
        fe = cfg.moe_d_ff or cfg.d_ff
        e = cfg.experts_eff
        p["ln2"] = jnp.ones((d,), jnp.float32)
        p["moe"] = {
            "router": dense_init(ks[3], (d, e)),
            "w_gate": dense_init(ks[4], (e, d, fe)),
            "w_up": dense_init(ks[5], (e, d, fe)),
            "w_down": dense_init(ks[6], (e, fe, d), scale=fe ** -0.5),
        }
        if e > cfg.num_experts:  # padded experts are never routed
            for kk in ("w_gate", "w_up", "w_down"):
                p["moe"][kk] = p["moe"][kk].at[cfg.num_experts:].set(0.0)
            p["moe"]["router"] = \
                p["moe"]["router"].at[:, cfg.num_experts:].set(0.0)
        if cfg.num_shared_experts:
            fs = cfg.num_shared_experts * fe
            p["moe"]["shared_gate"] = dense_init(ks[7], (d, fs))
            p["moe"]["shared_up"] = dense_init(ks[7], (d, fs))
            p["moe"]["shared_down"] = dense_init(ks[7], (fs, d),
                                                 scale=fs ** -0.5)
    elif cfg.d_ff:
        p["ln2"] = jnp.ones((d,), jnp.float32)
        p["mlp"] = {
            "wg": dense_init(ks[3], (d, cfg.d_ff)),
            "wu": dense_init(ks[4], (d, cfg.d_ff)),
            "wd": dense_init(ks[5], (cfg.d_ff, d), scale=cfg.d_ff ** -0.5),
        }
    return p


def init_params(cfg: ArchConfig, key) -> Params:
    kt, ke, kl, kenc, kh = jax.random.split(key, 5)

    def stack(k, fn, n):
        # n == 0 (cost-model variants): empty leading axis, scan runs 0 times
        m = max(n, 1)
        t = jax.tree.map(lambda *xs: jnp.stack(xs),
                         *[fn(kk) for kk in jax.random.split(k, m)])
        return t if n else jax.tree.map(lambda x: x[:0], t)
    params: Params = {
        "embed": embed_init(ke, (cfg.vocab_padded, cfg.d_model)),
        "ln_f": jnp.ones((cfg.d_model,), jnp.float32),
        "layers": stack(kl, lambda k: _layer_params(
            k, cfg, cross=cfg.is_encdec), cfg.num_layers),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(kh, (cfg.d_model, cfg.vocab_padded))
    if cfg.is_encdec:
        enc_cfg = cfg  # same widths for whisper-base
        params["enc_layers"] = stack(
            kenc, lambda k: _layer_params(k, enc_cfg), cfg.encoder_layers)
        params["enc_ln_f"] = jnp.ones((cfg.d_model,), jnp.float32)
    return params


def _sinusoid_pos(s: int, d: int, dtype):
    """Whisper-style fixed sinusoidal positions (no table: any length)."""
    pos = jnp.arange(s, dtype=jnp.float32)[:, None]
    inv = jnp.exp(-jnp.arange(0, d, 2, jnp.float32) / d * jnp.log(10000.0))
    ang = pos * inv[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dtype)



def _cast_layers(layers: Params, cfg: ArchConfig) -> Params:
    """cast_weights_once lever: convert >=2D f32 masters to the compute
    dtype OUTSIDE the layer scan, so sharded weight gathers move bf16.
    1D vectors (norms, biases, a_log, dt_bias) stay f32 for stability."""
    if not cfg.cast_weights_once:
        return layers
    cdt = jnp.dtype(cfg.dtype)

    def one(a):
        if a.ndim >= 3 and a.dtype == jnp.float32:  # stacked (L, ...) mats
            return a.astype(cdt)
        return a
    return jax.tree.map(one, layers)


# --------------------------------------------------------------------------
# layer forward pieces
# --------------------------------------------------------------------------
def _attention_block(h, lp, cfg: ArchConfig, positions, causal: bool,
                     kv_override=None, use_pallas: bool = False):
    """h: (B, S, D) normed input. kv_override: (k, v) for cross-attention."""
    b, s, d = h.shape
    dh = cfg.resolved_head_dim
    hq, hkv = cfg.q_heads_eff, cfg.kv_heads_eff
    cdt = h.dtype
    q = _constrain_bshd((h @ lp["wq"].astype(cdt)).reshape(b, s, hq, dh),
                        cfg)
    if kv_override is None:
        k = _constrain_bshd(
            (h @ lp["wk"].astype(cdt)).reshape(b, s, hkv, dh), cfg)
        v = _constrain_bshd(
            (h @ lp["wv"].astype(cdt)).reshape(b, s, hkv, dh), cfg)
    else:
        k, v = kv_override
    if cfg.qk_norm:
        q = rms_norm(q, lp["q_norm"])
        k = rms_norm(k, lp["k_norm"]) if kv_override is None else k
    # RoPE applies to self-attention only (cross-attention queries attend to
    # encoder states whose positions live in the encoder's learned table)
    if kv_override is None and positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if use_pallas:
        from repro.kernels import ops as kops
        o = kops.flash_attention(q.transpose(0, 2, 1, 3),
                                 k.transpose(0, 2, 1, 3),
                                 v.transpose(0, 2, 1, 3), causal=causal)
        o = o.transpose(0, 2, 1, 3)
    elif s >= 2048:
        o = attn_lib.chunked_attention(q, k, v, causal=causal)
    else:
        o = attn_lib.full_attention(q, k, v, causal=causal)
    o = _constrain_bshd(o, cfg)
    out = o.reshape(b, s, hq * dh) @ lp["wo"].astype(cdt)
    return out, (k, v)


def _ssm_block(h, lp, cfg: ArchConfig):
    """h: (B, S, D) normed input -> (B, S, D); full-sequence (train/prefill)."""
    b, s, d = h.shape
    hh, pp, nn = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    cdt = h.dtype
    x = h @ lp["in_x"].astype(cdt)  # (B,S,H*P)
    z = h @ lp["in_z"].astype(cdt)
    bb = h @ lp["in_b"].astype(cdt)  # (B,S,N)
    cc = h @ lp["in_c"].astype(cdt)
    dt = jax.nn.softplus(
        (h @ lp["in_dt"].astype(cdt)).astype(jnp.float32)
        + lp["dt_bias"][None, None])  # (B,S,H) f32
    conv_in = jnp.concatenate([x, bb, cc], axis=-1)
    conv_out, _ = ssm_lib.causal_conv(conv_in, lp["conv_w"].astype(cdt))
    conv_out = jax.nn.silu(conv_out)
    x, bb, cc = jnp.split(conv_out, [hh * pp, hh * pp + nn], axis=-1)
    xh = x.reshape(b, s, hh, pp)
    y, state = ssm_lib.ssd_chunked(xh, lp["a_log"], bb, cc, dt,
                                   chunk=min(cfg.ssm_chunk, s),
                                   return_state=True)
    y = y + xh * lp["d_skip"].astype(cdt)[None, None, :, None]
    y = y.reshape(b, s, hh * pp)
    y = rms_norm(y * jax.nn.silu(z), lp["ssm_norm"])
    return y @ lp["out"].astype(cdt), state, conv_in


def _ffn_block(x, lp, cfg: ArchConfig):
    """Returns (out, aux). x is the normed input."""
    cdt = x.dtype
    if cfg.num_experts:
        y, aux = moe_lib.moe_ffn(
            x, jax.tree.map(lambda a: a.astype(cdt), lp["moe"]),
            num_experts=cfg.experts_eff, top_k=cfg.experts_per_token,
            capacity_factor=cfg.capacity_factor,
            num_real_experts=cfg.num_experts)
        return y, aux
    y = swiglu(x, lp["mlp"]["wg"].astype(cdt), lp["mlp"]["wu"].astype(cdt),
               lp["mlp"]["wd"].astype(cdt))
    return y, None


def _decoder_block(x, lp, cfg: ArchConfig, positions, causal=True,
                   cross_kv=None, use_pallas=False):
    """Full-sequence decoder block. Returns (x, aux)."""
    h = rms_norm(x, lp["ln1"])
    mix = 0.0
    if cfg.has_attention:
        a, _ = _attention_block(h, lp["attn"], cfg, positions, causal,
                                use_pallas=use_pallas)
        mix = mix + a
    if cfg.has_ssm:
        sout, _, _ = _ssm_block(h, lp["ssm"], cfg)
        mix = mix + sout
    if cfg.has_attention and cfg.has_ssm:
        mix = mix * 0.5  # hymba: average the parallel heads
    x = x + mix
    if cross_kv is not None:
        hc = rms_norm(x, lp["ln_cross"])
        c, _ = _attention_block(hc, lp["cross"], cfg, positions, False,
                                kv_override=cross_kv)
        x = x + c
    aux = None
    if cfg.num_experts or cfg.d_ff:
        y, aux = _ffn_block(rms_norm(x, lp["ln2"]), lp, cfg)
        x = x + y
    return x, aux


# --------------------------------------------------------------------------
# full-sequence forward (train / prefill math)
# --------------------------------------------------------------------------
def _embed_inputs(params, cfg: ArchConfig, batch) -> tuple[Any, Any]:
    """Token + stub-modality embedding. batch keys: tokens (B, S_text);
    optional patches (B, P, D) [vlm]; frames (B, S_enc, D) [audio]."""
    cdt = jnp.dtype(cfg.dtype)
    emb = params["embed"].astype(cdt)
    x = emb[batch["tokens"]]
    if cfg.num_patches and "patches" in batch:
        x = jnp.concatenate([batch["patches"].astype(cdt), x], axis=1)
    return x


def encode(params, cfg: ArchConfig, frames):
    """Whisper encoder: frames (B, S_enc, D) stub embeddings -> (B, S_enc, D)."""
    cdt = jnp.dtype(cfg.dtype)
    s = frames.shape[1]
    x = frames.astype(cdt) + _sinusoid_pos(s, cfg.d_model, cdt)[None]

    def body(x, lp):
        x, _ = _decoder_block(x, lp, cfg, positions=None, causal=False)
        return x, None

    x, _ = lax.scan(body, x, _cast_layers(params["enc_layers"], cfg))
    return rms_norm(x, params["enc_ln_f"])


def forward(params, cfg: ArchConfig, batch, use_pallas: bool = False,
            remat: bool = True):
    """Returns (logits (B, S_total, V), aux dict)."""
    x = _embed_inputs(params, cfg, batch)
    b, s, _ = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)[None]
    if cfg.is_encdec:
        enc_out = encode(params, cfg, batch["frames"])

    def body(carry, lp):
        x = carry
        cross_kv = None
        if cfg.is_encdec:
            dh = cfg.resolved_head_dim
            be, se, _ = enc_out.shape
            ck = (enc_out @ lp["cross"]["wk"].astype(x.dtype)).reshape(
                be, se, cfg.kv_heads_eff, dh)
            cv = (enc_out @ lp["cross"]["wv"].astype(x.dtype)).reshape(
                be, se, cfg.kv_heads_eff, dh)
            cross_kv = (ck, cv)
        x, aux = _decoder_block(x, lp, cfg, positions, causal=True,
                                cross_kv=cross_kv, use_pallas=use_pallas)
        lb = (aux["lb_loss"] if aux else jnp.float32(0))
        zl = (aux["z_loss"] if aux else jnp.float32(0))
        load = (aux["expert_load"] if aux
                else jnp.zeros((max(cfg.num_experts, 1),)))
        return x, (lb, zl, load)

    if not remat or cfg.remat_policy == "none":
        block = body
    elif cfg.remat_policy == "save_dots":
        block = jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    elif cfg.remat_policy == "save_all_dots":
        # saves batched dots too (MoE expert einsums carry the E batch dim)
        block = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_saveable)
    else:
        block = jax.checkpoint(body)
    x, (lbs, zls, loads) = lax.scan(block, x,
                                    _cast_layers(params["layers"], cfg))
    x = rms_norm(x, params["ln_f"])
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(x.dtype)
    logits = x @ head
    aux = {"lb_loss": lbs.mean(), "z_loss": zls.mean(),
           "expert_load": loads.sum(0)}
    return logits, aux


# --------------------------------------------------------------------------
# serving: cache init / prefill / decode
# --------------------------------------------------------------------------
def init_cache(cfg: ArchConfig, batch: int, max_seq: int,
               enc_seq: int = 0) -> Params:
    cdt = jnp.dtype(cfg.dtype)
    dh = cfg.resolved_head_dim
    nl = cfg.num_layers
    cache: Params = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.has_attention:
        cache["k"] = jnp.zeros((nl, batch, max_seq, cfg.kv_heads_eff, dh),
                               cdt)
        cache["v"] = jnp.zeros((nl, batch, max_seq, cfg.kv_heads_eff, dh),
                               cdt)
    if cfg.has_ssm:
        cache["ssm_state"] = jnp.zeros(
            (nl, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
            jnp.float32)
        conv_ch = cfg.ssm_heads * cfg.ssm_head_dim + 2 * cfg.ssm_state
        cache["conv"] = jnp.zeros(
            (nl, batch, cfg.ssm_conv_width - 1, conv_ch), cdt)
    if cfg.is_encdec:
        cache["cross_k"] = jnp.zeros(
            (nl, batch, enc_seq, cfg.kv_heads_eff, dh), cdt)
        cache["cross_v"] = jnp.zeros(
            (nl, batch, enc_seq, cfg.kv_heads_eff, dh), cdt)
    return cache


def prefill(params, cfg: ArchConfig, batch, cache, use_pallas: bool = False):
    """Full-sequence prefill that also fills the cache.
    Returns (last-position logits (B, V), cache)."""
    x = _embed_inputs(params, cfg, batch)
    b, s, _ = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)[None]
    if cfg.is_encdec:
        enc_out = encode(params, cfg, batch["frames"])

    def body(x, lp_cache):
        lp, lcache = lp_cache
        cross_kv = None
        new_lcache = dict(lcache)
        if cfg.is_encdec:
            dh = cfg.resolved_head_dim
            be, se, _ = enc_out.shape
            ck = (enc_out @ lp["cross"]["wk"].astype(x.dtype)).reshape(
                be, se, cfg.kv_heads_eff, dh)
            cv = (enc_out @ lp["cross"]["wv"].astype(x.dtype)).reshape(
                be, se, cfg.kv_heads_eff, dh)
            cross_kv = (ck, cv)
            new_lcache["cross_k"], new_lcache["cross_v"] = ck, cv
        h = rms_norm(x, lp["ln1"])
        mix = 0.0
        if cfg.has_attention:
            a, (k, v) = _attention_block(h, lp["attn"], cfg, positions, True,
                                         use_pallas=use_pallas)
            kc, vc = attn_lib.update_cache(lcache["k"], lcache["v"], k, v, 0)
            new_lcache["k"], new_lcache["v"] = kc, vc
            mix = mix + a
        if cfg.has_ssm:
            sout, state, conv_in = _ssm_block(h, lp["ssm"], cfg)
            new_lcache["ssm_state"] = state
            new_lcache["conv"] = conv_in[:, -(cfg.ssm_conv_width - 1):, :]
            mix = mix + sout
        if cfg.has_attention and cfg.has_ssm:
            mix = mix * 0.5
        x = x + mix
        if cross_kv is not None:
            hc = rms_norm(x, lp["ln_cross"])
            c, _ = _attention_block(hc, lp["cross"], cfg, positions, False,
                                    kv_override=cross_kv)
            x = x + c
        if cfg.num_experts or cfg.d_ff:
            y, _ = _ffn_block(rms_norm(x, lp["ln2"]), lp, cfg)
            x = x + y
        return x, new_lcache

    layer_caches = {k: v for k, v in cache.items() if k != "pos"}
    x, new_layer_caches = lax.scan(
        body, x, (_cast_layers(params["layers"], cfg), layer_caches))
    x = rms_norm(x, params["ln_f"])
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(x.dtype)
    logits = x[:, -1] @ head
    new_cache = dict(new_layer_caches)
    new_cache["pos"] = jnp.asarray(s, jnp.int32)
    return logits, new_cache


def decode_step(params, cfg: ArchConfig, tokens, cache):
    """One decode step. tokens: (B, 1) int32. Returns (logits (B, V), cache)."""
    cdt = jnp.dtype(cfg.dtype)
    x = params["embed"].astype(cdt)[tokens]  # (B, 1, D)
    pos = cache["pos"]
    positions = pos[None, None]  # (1,1)
    dh = cfg.resolved_head_dim

    def body(x, lp_cache):
        lp, lcache = lp_cache
        new_lcache = dict(lcache)
        h = rms_norm(x, lp["ln1"])
        b = h.shape[0]
        mix = 0.0
        if cfg.has_attention:
            ap = lp["attn"]
            q = (h @ ap["wq"].astype(cdt)).reshape(b, 1, cfg.q_heads_eff,
                                                    dh)
            k = (h @ ap["wk"].astype(cdt)).reshape(b, 1, cfg.kv_heads_eff,
                                                    dh)
            v = (h @ ap["wv"].astype(cdt)).reshape(b, 1, cfg.kv_heads_eff,
                                                    dh)
            if cfg.qk_norm:
                q = rms_norm(q, ap["q_norm"])
                k = rms_norm(k, ap["k_norm"])
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
            kc, vc = attn_lib.update_cache(lcache["k"], lcache["v"],
                                           k, v, pos)
            new_lcache["k"], new_lcache["v"] = kc, vc
            o = attn_lib.decode_attention(q, kc, vc, pos)
            mix = mix + o.reshape(b, 1, cfg.q_heads_eff * dh) @ \
                ap["wo"].astype(cdt)
        if cfg.has_ssm:
            sp = lp["ssm"]
            hh, pp, nn = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
            xs = h @ sp["in_x"].astype(cdt)
            z = h @ sp["in_z"].astype(cdt)
            bb = h @ sp["in_b"].astype(cdt)
            cc = h @ sp["in_c"].astype(cdt)
            dt = jax.nn.softplus(
                (h @ sp["in_dt"].astype(cdt)).astype(jnp.float32)
                + sp["dt_bias"][None, None])
            conv_in = jnp.concatenate([xs, bb, cc], axis=-1)
            conv_out, conv_cache = ssm_lib.causal_conv(
                conv_in, sp["conv_w"].astype(cdt), cache=lcache["conv"])
            new_lcache["conv"] = conv_cache
            conv_out = jax.nn.silu(conv_out)
            xs, bb, cc = jnp.split(conv_out, [hh * pp, hh * pp + nn], -1)
            state, y = ssm_lib.ssd_decode_step(
                lcache["ssm_state"], xs.reshape(b, hh, pp), sp["a_log"],
                bb[:, 0], cc[:, 0], dt[:, 0])
            new_lcache["ssm_state"] = state
            y = y[:, None] + xs.reshape(b, 1, hh, pp) * \
                sp["d_skip"].astype(cdt)[None, None, :, None]
            y = rms_norm(y.reshape(b, 1, hh * pp) * jax.nn.silu(z),
                         sp["ssm_norm"])
            mix = mix + y @ sp["out"].astype(cdt)
        if cfg.has_attention and cfg.has_ssm:
            mix = mix * 0.5
        x = x + mix
        if cfg.is_encdec:
            hc = rms_norm(x, lp["ln_cross"])
            c, _ = _attention_block(
                hc, lp["cross"], cfg, positions, False,
                kv_override=(lcache["cross_k"], lcache["cross_v"]))
            x = x + c
        if cfg.num_experts or cfg.d_ff:
            y, _ = _ffn_block(rms_norm(x, lp["ln2"]), lp, cfg)
            x = x + y
        return x, new_lcache

    layer_caches = {k: v for k, v in cache.items() if k != "pos"}
    x, new_layer_caches = lax.scan(
        body, x, (_cast_layers(params["layers"], cfg), layer_caches))
    x = rms_norm(x, params["ln_f"])
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(x.dtype)
    logits = x[:, 0] @ head
    new_cache = dict(new_layer_caches)
    new_cache["pos"] = pos + 1
    return logits, new_cache
