"""Batched serving example: prefill + greedy decode on the hybrid arch.

    PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main(["--arch", "hymba_1p5b", "--reduced", "--batch", "4",
          "--prompt-len", "32", "--gen", "16"])
