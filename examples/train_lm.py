"""End-to-end training driver: ~100M-param llama-style model, few hundred
steps on the synthetic pipeline, with checkpoint/resume.

    PYTHONPATH=src python examples/train_lm.py
"""
from repro.launch.train import main

if __name__ == "__main__":
    # ~100M params: reduced llama3.2 scaled up (d_model 512, 8 layers,
    # vocab 128) trained 200 steps; loss should drop markedly.
    main(["--arch", "llama3p2_1b", "--reduced", "--scale", "4",
          "--steps", "200", "--batch", "16", "--seq", "128",
          "--ckpt-dir", "/tmp/repro_ckpt_example", "--log-every", "20"])
