"""Query-serving subsystem: multi-lane execution, session batching, and
the two acceptance properties — (1) the single-lane service path
reproduces the engine trajectory EXACTLY (serving is a strict superset of
the engine, not a fork), and (2) snapshot isolation: answers under
concurrent ingest equal answers on the pinned epoch's frozen graph,
including delete batches."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from conftest import bellman_ford_oracle, ppr_oracle
from repro.core import algorithms as A
from repro.core import graph as G
from repro.core.engine import EngineConfig, StructureAwareEngine
from repro.serve import Query, QueryService
from repro.stream import (DeltaBatch, StreamConfig, StreamingEngine,
                          synthetic_stream)
from repro.stream.delta import apply_to_coo

CFG = EngineConfig(t2=1e-9, width=4, block_size=128)


def _close(a, b, **kw):
    return np.allclose(np.minimum(a, 1e18), np.minimum(b, 1e18), **kw)


def _frozen(g, batches, upto):
    s, d, w = G.edges_of(g)
    for b in batches[:upto]:
        s, d, w = apply_to_coo(s, d, w, g.n, b)
    return G.from_edges(g.n, s, d, w)


@pytest.fixture(scope="module")
def stream_pl():
    g = G.powerlaw_graph(900, avg_deg=5, seed=7, weighted=True)
    return g, StreamingEngine(g, A.pagerank(), CFG)


# -- single-lane parity: serving is a strict superset of the engine ----------
def test_single_lane_reproduces_engine_trajectory(stream_pl):
    """A one-query service run must be indistinguishable from a plain
    engine run of the same program on the same epoch: same iteration
    count, same values (bitwise), same update/load/byte accounting — the
    shared decision helpers make the schedules identical and the lane
    arithmetic is the engine arithmetic with a unit lane axis."""
    g, se = stream_pl
    svc = QueryService(se, max_lanes=1)
    svc.submit(Query(kind="sssp", source=3))
    r = svc.run_pending()[0]
    ref = StructureAwareEngine(g, A.sssp(3), se.config).run()
    assert r.converged and ref.metrics.converged
    assert r.iterations == ref.metrics.iterations
    assert r.batch_iterations == ref.metrics.iterations
    assert np.array_equal(r.values, ref.values)


def test_padding_lanes_do_not_perturb_trajectory(stream_pl):
    """A single admitted query in a padded L=4 batch takes the same
    trajectory as the engine: padding lanes start individually converged,
    never hold a block in the active set, and are masked out of the
    folded block priority."""
    g, se = stream_pl
    svc = QueryService(se, max_lanes=4)
    svc.submit(Query(kind="sssp", source=3))
    r = svc.run_pending()[0]
    ref = StructureAwareEngine(g, A.sssp(3), se.config).run()
    assert r.batch_iterations == ref.metrics.iterations
    assert np.array_equal(r.values, ref.values)
    m = svc.metrics
    assert m.lanes_admitted == 1 and m.lane_slots == 4
    assert m.lane_utilization == pytest.approx(0.25)


def test_lane_engine_counters_match_engine(stream_pl):
    """Metric accounting of a unit-lane batch equals the engine's:
    loads/bytes are billed per block schedule, updates/edges per admitted
    lane — with one lane both reduce to the engine's numbers exactly."""
    import jax.numpy as jnp
    from repro.core.engine import coupling_from_counts
    from repro.serve.lanes import LaneEngine
    g, se = stream_pl
    es = se.snapshot()
    fam = A.k_source_sssp()
    le = LaneEngine(es.engine, fam)
    vals0, vconst = fam.lane_init(se.n, [3])
    res = le.run(
        ed=es.ed._replace(aux=jnp.zeros(se.n, jnp.float32)),
        coupling=coupling_from_counts(es.coupling_counts, fam,
                                      es.engine.plan.block_size),
        values0=vals0, vconst=vconst, lane_active=np.array([True]),
        edge_counts=es.edge_counts)
    ref = StructureAwareEngine(g, A.sssp(3), se.config).run()
    for f in ("iterations", "updates", "edges_processed", "block_loads",
              "bytes_loaded", "converged"):
        assert getattr(res.metrics, f) == getattr(ref.metrics, f), f


# -- multi-lane correctness ---------------------------------------------------
def test_k_source_sssp_lanes_match_oracles(stream_pl):
    g, se = stream_pl
    svc = QueryService(se, max_lanes=4)
    sources = [0, 7, 42, 130]
    qids = [svc.submit(Query(kind="sssp", source=s)) for s in sources]
    res = {r.query_id: r for r in svc.run_pending()}
    assert len(res) == 4
    by_qid = dict(zip(qids, sources))
    for qid, r in res.items():
        oracle = bellman_ford_oracle(g, by_qid[qid])
        assert r.converged
        assert _close(r.values, oracle.astype(np.float32), rtol=1e-5,
                      atol=1e-3)
    # one fused batch served all four queries
    assert svc.metrics.lane_batches == 1
    assert svc.metrics.queries == 4


def test_k_source_bfs_lanes_match_oracles(stream_pl):
    g, se = stream_pl
    svc = QueryService(se, max_lanes=2)
    qids = [svc.submit(Query(kind="bfs", source=s)) for s in (1, 9)]
    res = {r.query_id: r for r in svc.run_pending()}
    for qid, s in zip(qids, (1, 9)):
        oracle = bellman_ford_oracle(g, s, unit=True)
        assert _close(res[qid].values, oracle.astype(np.float32),
                      rtol=1e-5, atol=1e-3)


def test_ppr_lanes_match_power_iteration(stream_pl):
    g, se = stream_pl
    svc = QueryService(se, max_lanes=2)
    resets = [[0], [5, 17, 200]]
    qids = [svc.submit(Query(kind="ppr", reset=r)) for r in resets]
    res = {r.query_id: r for r in svc.run_pending()}
    for qid, rs in zip(qids, resets):
        oracle = ppr_oracle(g, rs)
        assert res[qid].converged
        assert np.allclose(res[qid].values, oracle, rtol=1e-3, atol=1e-6)
        # a personalized vector concentrates mass near its reset set
        assert res[qid].values[rs[0]] > 1.0 / g.n


def test_mixed_kinds_batch_per_family(stream_pl):
    """sssp and ppr queries cannot share a lane batch (different edge_map
    / combine): the session scheduler groups by family and runs one
    fused batch per group."""
    g, se = stream_pl
    svc = QueryService(se, max_lanes=4)
    svc.submit(Query(kind="sssp", source=2))
    svc.submit(Query(kind="ppr", reset=[3]))
    svc.submit(Query(kind="sssp", source=11))
    res = svc.run_pending()
    assert len(res) == 3
    assert svc.metrics.lane_batches == 2
    kinds = {r.kind for r in res}
    assert kinds == {"sssp", "ppr"}


def test_admission_priority_hottest_frontier_first(stream_pl):
    """PSD-priority admission: with more pending queries than lanes, the
    lane slots go to the hottest seed frontiers (paper Eq. 1 activity)
    first; ties keep submit order."""
    g, se = stream_pl
    act = se.activity()
    cold_v = int(np.argmin(act))
    hot_v = int(np.argmax(act))
    svc = QueryService(se, max_lanes=2)
    q_cold = svc.submit(Query(kind="sssp", source=cold_v))
    q_hot = svc.submit(Query(kind="sssp", source=hot_v))
    q_mid = svc.submit(Query(kind="sssp", source=int(np.argsort(act)[g.n // 2])))
    res = svc.run_pending()
    # completion order is batch order: the hot query must land in the
    # first batch of two, the cold one waits for the second
    first_batch = [r.query_id for r in res if r.lanes == 2]
    second_batch = [r.query_id for r in res if r.lanes == 1]
    assert q_hot in first_batch and q_mid in first_batch
    assert second_batch == [q_cold]


# -- snapshot isolation -------------------------------------------------------
@given(seed=st.integers(0, 15), kind=st.sampled_from(["sssp", "ppr"]))
@settings(max_examples=6, deadline=None)
def test_snapshot_isolation_property(seed, kind):
    """Acceptance property: a query admitted at epoch e answers on the
    graph AS OF epoch e — bit-for-bit the frozen snapshot's fixpoint —
    no matter how many delta batches (including deletes) are ingested
    between submission and execution."""
    g = G.powerlaw_graph(400, avg_deg=4, seed=seed, weighted=True)
    se = StreamingEngine(g, A.pagerank(), CFG)
    svc = QueryService(se, max_lanes=2, prewarm=False)
    batches = synthetic_stream(g, 2, 50, seed=seed + 1, delete_frac=0.4,
                               weighted=True)
    mk = (lambda s: Query(kind="sssp", source=s)) if kind == "sssp" else \
        (lambda s: Query(kind="ppr", reset=[s, (s + 3) % g.n]))
    q0 = svc.submit(mk(0))  # pinned to epoch 0 (the original graph)
    svc.ingest(batches[0])
    q1 = svc.submit(mk(0))  # pinned to epoch 1
    svc.ingest(batches[1])  # epoch-1 pin must survive this one too
    res = {r.query_id: r for r in svc.run_pending()}
    assert res[q0].epoch == 0 and res[q1].epoch == 1
    for qid, upto in ((q0, 0), (q1, 1)):
        frozen = _frozen(g, batches, upto)
        if kind == "sssp":
            oracle = bellman_ford_oracle(frozen, 0).astype(np.float32)
            assert _close(res[qid].values, oracle, rtol=1e-5, atol=1e-3), \
                f"epoch {upto} answer diverged from its frozen graph"
        else:
            oracle = ppr_oracle(frozen, [0, 3])
            assert np.allclose(res[qid].values, oracle, rtol=1e-3,
                               atol=1e-6)
    assert svc.metrics.stale_answers == 2  # both served after more ingests
    assert se.metrics.snapshots_preserved >= 1


def test_snapshot_survives_plan_rebuild():
    """The hard isolation case: the concurrent ingest overflows a tile run
    and rebuilds the whole plan (new permutation, new engine, new
    compiled functions) — the pinned query must still answer on its
    frozen pre-ingest graph through the preserved epoch state."""
    g = G.powerlaw_graph(300, avg_deg=4, seed=1, weighted=True)
    se = StreamingEngine(g, A.pagerank(), CFG,
                         StreamConfig(tile_slack=0.0, spare_tiles=0))
    svc = QueryService(se, max_lanes=2, prewarm=False)
    qid = svc.submit(Query(kind="sssp", source=0))
    burst = DeltaBatch(ins_src=np.arange(250) % g.n,
                       ins_dst=np.full(250, 7),
                       ins_w=np.ones(250, np.float32),
                       del_src=[], del_dst=[])
    rep = svc.ingest(burst)
    assert rep.plan_rebuild
    r = {x.query_id: x for x in svc.run_pending()}[qid]
    oracle = bellman_ford_oracle(g, 0).astype(np.float32)  # PRE-burst graph
    assert r.epoch == 0
    assert _close(r.values, oracle, rtol=1e-5, atol=1e-3)
    # and a fresh query sees the post-burst epoch
    q2 = svc.submit(Query(kind="sssp", source=0))
    r2 = {x.query_id: x for x in svc.run_pending()}[q2]
    oracle2 = bellman_ford_oracle(_frozen(g, [burst], 1), 0) \
        .astype(np.float32)
    assert r2.epoch == 1
    assert _close(r2.values, oracle2, rtol=1e-5, atol=1e-3)


def test_pins_cost_nothing_on_quiet_graph(stream_pl):
    """Epoch pinning is free until an ingest actually lands: no device
    copy happens for queries that run before any mutation."""
    g, se = stream_pl
    before = se.metrics.snapshots_preserved
    svc = QueryService(se, max_lanes=2, prewarm=False)
    svc.submit(Query(kind="bfs", source=0))
    svc.run_pending()
    assert se.metrics.snapshots_preserved == before


# -- validation / bookkeeping -------------------------------------------------
def test_query_validation(stream_pl):
    g, se = stream_pl
    svc = QueryService(se, max_lanes=2, prewarm=False)
    with pytest.raises(ValueError):
        svc.submit(Query(kind="nope", source=0))
    with pytest.raises(ValueError):
        svc.submit(Query(kind="sssp", source=g.n))
    with pytest.raises(ValueError):
        svc.submit(Query(kind="sssp"))
    with pytest.raises(ValueError):
        svc.submit(Query(kind="ppr"))
    # malformed ppr resets are rejected AT SUBMIT (a bad lane admitted
    # into a batch would take its batchmates down at run time)
    with pytest.raises(ValueError):
        svc.submit(Query(kind="ppr", reset=[]))
    with pytest.raises(ValueError):
        svc.submit(Query(kind="ppr", reset=[g.n]))
    with pytest.raises(ValueError):
        svc.submit(Query(kind="ppr", reset=[-1]))
    with pytest.raises(ValueError):  # dense reset that is not a distribution
        svc.submit(Query(kind="ppr",
                         reset=np.full(g.n, 2.0 / g.n, np.float32)))
    with pytest.raises(ValueError):
        QueryService(se, max_lanes=0)
    assert svc.pending == 0


def test_failing_batch_does_not_discard_other_queries(stream_pl, monkeypatch):
    """A batch that errors mid-run consumes only its own queries: every
    other pending batch stays queued and is served by the next
    run_pending call."""
    g, se = stream_pl
    svc = QueryService(se, max_lanes=2, prewarm=False)
    q_ppr = svc.submit(Query(kind="ppr", reset=[3]))
    q_sssp = svc.submit(Query(kind="sssp", source=1))
    calls = {"n": 0}
    real = QueryService._run_batch

    def boom_first(self, pend):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("lane batch died")
        return real(self, pend)

    monkeypatch.setattr(QueryService, "_run_batch", boom_first)
    with pytest.raises(RuntimeError):
        svc.run_pending()
    assert svc.pending == 1  # the other batch survived the failure
    res = svc.run_pending()
    assert len(res) == 1
    assert res[0].query_id in (q_ppr, q_sssp)


def test_same_epoch_pins_share_one_device_copy():
    """N pins of one epoch cost ONE O(m) device copy at the next ingest,
    not N (the pins are read-only views of identical state)."""
    g = G.powerlaw_graph(250, avg_deg=4, seed=2, weighted=True)
    se = StreamingEngine(g, A.pagerank(), CFG)
    pins = [se.snapshot() for _ in range(3)]
    se.ingest(DeltaBatch.of(ins=[(0, 1)]))
    assert se.metrics.snapshots_preserved == 1
    assert all(p.preserved for p in pins)
    assert pins[1].ed is pins[0].ed and pins[2].ed is pins[0].ed


def test_symmetric_host_rejects_asymmetric_family():
    """A cc host engine stores the symmetrized tiles; traversal lanes over
    them would answer the wrong graph — refused at admission."""
    g = G.powerlaw_graph(200, avg_deg=3, seed=0)
    se = StreamingEngine(g, A.cc(), CFG)
    svc = QueryService(se, max_lanes=2, prewarm=False)
    with pytest.raises(ValueError):
        svc.submit(Query(kind="sssp", source=0))


def test_serve_metrics_accumulate(stream_pl):
    g, se = stream_pl
    svc = QueryService(se, max_lanes=2)
    for s in (0, 1, 2):
        svc.submit(Query(kind="bfs", source=s))
    res = svc.run_pending()
    m = svc.metrics
    assert m.queries == 3 and m.lane_batches == 2
    assert m.lanes_admitted == 3 and m.lane_slots == 4
    assert m.run_time_s > 0 and m.iterations > 0
    assert m.epochs_pinned >= 1
    d = m.as_dict()
    assert "queries_per_s" in d and "lane_utilization" in d
    assert all(r.run_s > 0 for r in res)
    assert svc.pending == 0
