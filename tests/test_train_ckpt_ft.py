"""Training loop, checkpoint/restart, fault-tolerance substrate."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.ckpt import CheckpointManager
from repro.data import SyntheticLM
from repro.ft import StragglerMonitor
from repro.models import model as M
from repro.optim import (AdamWConfig, adamw_init, adamw_update, cosine_lr,
                         int8_decode, int8_encode)
from repro.train.step import make_train_step


def _tiny_state(cfg, seed=0):
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    return {"params": params, "opt": adamw_init(params)}


def test_loss_decreases():
    cfg = configs.reduced(configs.get("llama3p2_1b"))
    data = SyntheticLM(cfg.vocab_size, 64, 8, seed=0)
    step = jax.jit(make_train_step(cfg, AdamWConfig(
        peak_lr=3e-3, warmup_steps=10, total_steps=150)))
    state = _tiny_state(cfg)
    losses = []
    for i in range(150):
        state, m = step(state, data.batch(i))
        losses.append(float(m["loss"]))
    # the synthetic affine-recurrence task is learnable: demand a solid drop
    assert np.mean(losses[-10:]) < 0.8 * np.mean(losses[:5]), \
        (losses[:5], losses[-5:])


def test_microbatch_equivalence():
    """micro=1 and micro=4 must produce (numerically close) identical
    updates — gradient accumulation correctness."""
    cfg = configs.reduced(configs.get("llama3p2_1b"))
    data = SyntheticLM(cfg.vocab_size, 32, 8, seed=1)
    batch = data.batch(0)
    s1 = _tiny_state(cfg, seed=3)
    s4 = jax.tree.map(jnp.copy, s1)
    opt = AdamWConfig(peak_lr=1e-3, warmup_steps=1, total_steps=10)
    st1, m1 = jax.jit(make_train_step(cfg, opt, num_microbatches=1))(
        s1, batch)
    st4, m4 = jax.jit(make_train_step(cfg, opt, num_microbatches=4))(
        s4, batch)
    np.testing.assert_allclose(float(m1["ce"]), float(m4["ce"]), rtol=1e-4)
    np.testing.assert_allclose(float(m1["grad_norm"]),
                               float(m4["grad_norm"]), rtol=1e-3)
    # Adam's first step is ~sign(g)*lr: bf16 accumulation noise can flip the
    # sign of near-zero grads, so params agree only to a few lr units.
    a = jax.tree.leaves(st1["params"])
    b = jax.tree.leaves(st4["params"])
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32),
                                   rtol=5e-2, atol=5e-3)


def test_adamw_math_vs_reference():
    cfg = AdamWConfig(peak_lr=1e-2, warmup_steps=0, total_steps=100,
                      weight_decay=0.0, clip_norm=1e9)
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.1, 0.2])}
    st = adamw_init(p)
    new_p, st, _ = adamw_update(g, st, p, cfg)
    m = 0.1 * np.array([0.1, 0.2])
    v = 0.05 * np.array([0.1, 0.2]) ** 2
    mhat, vhat = m / 0.1, v / 0.05
    lr = float(cosine_lr(cfg, 1))
    want = np.array([1.0, -2.0]) - lr * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(new_p["w"], want, rtol=1e-5)


def test_cosine_schedule_shape():
    cfg = AdamWConfig(peak_lr=1.0, min_lr=0.1, warmup_steps=10,
                      total_steps=100)
    assert float(cosine_lr(cfg, 0)) == 0.0
    assert abs(float(cosine_lr(cfg, 10)) - 1.0) < 1e-6
    assert abs(float(cosine_lr(cfg, 100)) - 0.1) < 1e-6
    assert float(cosine_lr(cfg, 55)) > float(cosine_lr(cfg, 90))


# -- checkpointing ------------------------------------------------------------
def test_ckpt_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    tree = {"a": np.arange(6).reshape(2, 3),
            "nested": {"b": np.ones(4, np.float32)}}
    mgr.save(5, tree, extra_meta={"note": "x"})
    got, meta = mgr.restore()
    assert meta["step"] == 5 and meta["note"] == "x"
    np.testing.assert_array_equal(got["a"], tree["a"])
    np.testing.assert_array_equal(got["nested"]["b"], tree["nested"]["b"])


def test_ckpt_keep_n_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": np.array([s])})
    assert mgr.list_steps() == [3, 4]
    got, meta = mgr.restore()
    assert meta["step"] == 4 and got["x"][0] == 4


def test_ckpt_async_and_atomic(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_write=True)
    mgr.save(1, {"x": np.zeros(1000)})
    mgr.wait()
    names = os.listdir(tmp_path)
    assert "step_00000001" in names
    assert not any(n.endswith(".tmp") for n in names)


def test_ckpt_treedef_container_types(tmp_path):
    """list/tuple nodes must come back as lists/tuples (the recorded
    treedef, not the key-only dict fallback), and leaf dtypes must
    survive — an np.int32 scalar is still int32 after the round trip."""
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    tree = {"edges": (np.arange(3, dtype=np.int64),
                      np.arange(3, dtype=np.int64),
                      np.ones(3, np.float32)),
            "hist": [np.zeros(2), {"inner": (np.int32(7), [np.float32(1.5)])}],
            "step": np.int32(11)}
    mgr.save(1, tree)
    got, meta = mgr.restore()
    assert isinstance(got["edges"], tuple) and len(got["edges"]) == 3
    assert isinstance(got["hist"], list)
    assert isinstance(got["hist"][1]["inner"], tuple)
    assert isinstance(got["hist"][1]["inner"][1], list)
    assert got["edges"][2].dtype == np.float32
    assert got["step"].dtype == np.int32
    assert got["hist"][1]["inner"][0].dtype == np.int32
    np.testing.assert_array_equal(got["edges"][0], tree["edges"][0])
    # pre-treedef checkpoints (no spec in meta) still restore, dict-shaped
    meta_path = os.path.join(str(tmp_path), "step_00000001", "meta.json")
    import json
    with open(meta_path) as f:
        m = json.load(f)
    del m["treedef"]
    with open(meta_path, "w") as f:
        json.dump(m, f)
    old, _ = mgr.restore()
    assert isinstance(old["edges"], dict)  # fallback loses container types
    np.testing.assert_array_equal(old["edges"]["0"], tree["edges"][0])


def test_ckpt_stale_tmp_sweep_crash_recovery(tmp_path):
    """A crash mid-write leaves step_*.tmp garbage; a fresh manager must
    sweep it so a rewrite of the same step publishes cleanly, and the
    half-written tmp must never be visible as a restorable step."""
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(1, {"x": np.array([1.0])})
    # simulate a crash mid-write of step 2: tmp dir with a partial npz
    stale = os.path.join(str(tmp_path), "step_00000002.tmp")
    os.makedirs(stale)
    with open(os.path.join(stale, "arrays.npz"), "w") as f:
        f.write("partial")
    assert mgr.list_steps() == [1]  # tmp is not a step
    got, meta = mgr.restore()
    assert meta["step"] == 1
    # recovery: a new manager (the restarted process) sweeps the garbage
    mgr2 = CheckpointManager(str(tmp_path), async_write=False)
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))
    mgr2.save(2, {"x": np.array([2.0])})
    got, meta = mgr2.restore()
    assert meta["step"] == 2 and got["x"][0] == 2.0


def test_resume_equivalence(tmp_path):
    """train 6 steps == train 3, checkpoint, restore, train 3 more."""
    cfg = configs.reduced(configs.get("llama3p2_1b"))
    data = SyntheticLM(cfg.vocab_size, 32, 4, seed=7)
    opt = AdamWConfig(peak_lr=1e-3, warmup_steps=1, total_steps=10)
    step = jax.jit(make_train_step(cfg, opt))

    s = _tiny_state(cfg, seed=9)
    for i in range(6):
        s, m6 = step(s, data.batch(i))

    s2 = _tiny_state(cfg, seed=9)
    for i in range(3):
        s2, _ = step(s2, data.batch(i))
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(3, s2)
    restored, meta = mgr.restore()
    restored = jax.tree.map(jnp.asarray, restored)
    restored["opt"]["step"] = jnp.asarray(restored["opt"]["step"],
                                          jnp.int32)
    for i in range(meta["step"], 6):
        restored, mr = step(restored, data.batch(i))
    np.testing.assert_allclose(float(m6["loss"]), float(mr["loss"]),
                               rtol=1e-5)


# -- fault tolerance ----------------------------------------------------------
def test_straggler_monitor():
    mon = StragglerMonitor(deadline_factor=2.0, evict_after=2)
    for _ in range(10):
        h = mon.observe(1.0)
        assert not h["straggler"]
    h = mon.observe(5.0)
    assert h["straggler"] and not h["evict"]
    h = mon.observe(5.0)
    assert h["straggler"] and h["evict"]
    # healthy step resets the eviction counter
    mon2 = StragglerMonitor(deadline_factor=2.0, evict_after=2)
    mon2.observe(1.0)
    mon2.observe(5.0)
    mon2.observe(1.0)
    h = mon2.observe(5.0)
    assert not h["evict"]


def test_int8_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=1000).astype(np.float32))
    q, s = int8_encode(x)
    err = np.abs(np.asarray(int8_decode(q, s) - x))
    assert err.max() <= float(s) * 0.5 + 1e-7


def test_error_feedback_unbiased_over_time():
    """EF: the RUNNING SUM of compressed grads tracks the true sum (the
    residual re-injects what quantization dropped)."""
    rng = np.random.default_rng(1)
    true_sum = np.zeros(64)
    got_sum = np.zeros(64)
    resid = jnp.zeros(64)
    for _ in range(50):
        g = rng.normal(size=64).astype(np.float32) * 0.01
        true_sum += g
        gf = jnp.asarray(g) + resid
        q, s = int8_encode(gf)
        deq = int8_decode(q, s)
        resid = gf - deq
        got_sum += np.asarray(deq)
    # with EF the cumulative error stays bounded by one quantization step
    assert np.abs(got_sum - true_sum).max() < 0.01
