# One function per paper table. Prints ``name,us_per_call,derived`` CSV;
# ``--json`` additionally writes BENCH_runtime.json so PRs can track the
# perf trajectory.
from __future__ import annotations

import argparse
import json
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20000,
                    help="graph size for the engine benchmarks")
    ap.add_argument("--only", default=None,
                    help="comma list: runtime,convergence,io,kernels,"
                         "streaming")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_runtime.json (suite, name, "
                         "us_per_call) next to the CSV output")
    args = ap.parse_args()

    from benchmarks import (bench_convergence, bench_io, bench_kernels,
                            bench_runtime, bench_streaming)
    suites = {
        "runtime": lambda: bench_runtime.run(args.n),
        "convergence": lambda: bench_convergence.run(args.n),
        "io": lambda: bench_io.run(args.n),
        "kernels": bench_kernels.run,
        "streaming": lambda: bench_streaming.run(args.n),
    }
    pick = args.only.split(",") if args.only else list(suites)
    if args.json and "io" not in pick:
        # the bytes-loaded trajectory is tracked across PRs: a JSON payload
        # without the I/O table rows silently drops it
        pick.append("io")
    print("name,us_per_call,derived")
    ok = True
    records = []
    for key in pick:
        try:
            rows = suites[key]()
        except ImportError:
            # a suite that cannot even import is a broken harness, not a
            # data point — fail loudly instead of emitting an ERROR row
            raise
        except Exception as e:  # noqa: BLE001
            ok = False
            print(f"{key},-1,ERROR:{e!r}")
            # keep the failure in-band in the JSON payload too: a suite's
            # rows silently vanishing would read as a perf change
            records.append({"suite": key, "name": key, "us_per_call": -1,
                            "derived": f"ERROR:{e!r}"})
            continue
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
            sys.stdout.flush()
            records.append({"suite": key, "name": name,
                            "us_per_call": round(float(us), 1),
                            "derived": derived})
    if args.json:
        with open("BENCH_runtime.json", "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote BENCH_runtime.json ({len(records)} rows)",
              file=sys.stderr)
    if not ok:
        sys.exit(1)


if __name__ == '__main__':
    main()
