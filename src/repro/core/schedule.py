"""Adaptive partition scheduling (paper Alg. 3, §4).

Each iteration selects the m highest-PSD hot blocks; every I2-th iteration it
also admits the n highest-PSD cold blocks, with m + n = the worker count
(paper: the CPU count; here: the schedule width = devices on the data axis x
blocks per device) and m > n. When no hot blocks remain, the full width goes
to the highest-PSD cold blocks.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Selection:
    hot_ids: np.ndarray  # (<=m,) global block ids scheduled in async mode
    cold_ids: np.ndarray  # (<=n or <=W,) block ids scheduled in sync mode


@dataclasses.dataclass
class Scheduler:
    width: int  # W = m + n
    i2: int = 4  # cold-admission cadence
    cold_frac: float = 0.25  # n = floor(W * cold_frac) (m > n per the paper)
    min_psd: float = 0.0  # prune individually-converged blocks (see engine)

    def select(self, iteration: int, psd: np.ndarray,
               is_hot: np.ndarray) -> Selection:
        w = self.width
        live = psd >= self.min_psd  # safe: if ALL pruned, sum(psd) < T2
        hot_ids = np.flatnonzero(is_hot & live)
        cold_ids = np.flatnonzero(~is_hot & live)
        if hot_ids.size == 0:  # "only remains P_cold"
            pick = cold_ids[np.argsort(-psd[cold_ids], kind="stable")][:w]
            return Selection(hot_ids=np.empty(0, np.int64), cold_ids=pick)

        if self.i2 and iteration % self.i2 == 0:
            # I2 iteration: m hot + n cold (m > n), paper Alg. 3.
            n = int(w * self.cold_frac)
            m = w - n
        else:
            # non-I2 iteration: hot partitions have absolute priority...
            m, n = w, 0
        hot_pick = hot_ids[np.argsort(-psd[hot_ids], kind="stable")][:m]
        # ...but scheduling is work-conserving: idle workers (fewer live hot
        # blocks than m) take the next-hottest cold blocks instead of
        # idling — "ensure that the hot partition is sufficiently computed"
        # constrains priority, not utilization.
        n = w - hot_pick.size if hot_pick.size < m else n
        cold_pick = cold_ids[np.argsort(-psd[cold_ids], kind="stable")][:n]
        return Selection(hot_ids=hot_pick, cold_ids=cold_pick)
