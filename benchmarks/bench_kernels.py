"""Kernel microbenchmarks: Pallas (interpret on CPU — correctness-path
timing only; TPU timing comes from the roofline terms) vs jnp oracles, plus
the XLA paths the models actually lower on this host."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.models.attention import chunked_attention, full_attention
from repro.models.ssm import ssd_chunked


def _time(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rng = np.random.default_rng(0)
    rows = []
    # spmv: jnp scatter-add oracle vs Pallas(one-hot MXU formulation,
    # interpret) — report both
    e, c = 8192, 512
    msg = jnp.asarray(rng.normal(size=e).astype(np.float32))
    dst = jnp.asarray(rng.integers(0, c, size=e).astype(np.int32))
    jr = jax.jit(lambda m, d: ref.edge_block_sum(m, d, c))
    rows.append((f"kernels/spmv_ref_E{e}_C{c}", _time(jr, msg, dst), "jnp"))
    rows.append((f"kernels/spmv_pallas_E{e}_C{c}",
                 _time(lambda m, d: ops.edge_block_sum(m, d, c), msg, dst),
                 "interpret=True (correctness path)"))
    # attention: chunked (the lowered path) vs full reference
    q = jnp.asarray(rng.normal(size=(1, 2048, 8, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 2048, 2, 64)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 2048, 2, 64)).astype(np.float32))
    rows.append(("kernels/attn_full_2k",
                 _time(jax.jit(lambda a, b_, c_: full_attention(a, b_, c_)),
                       q, k, v), "quadratic"))
    rows.append(("kernels/attn_chunked_2k",
                 _time(lambda a, b_, c_: chunked_attention(a, b_, c_),
                       q, k, v), "online-softmax (prefill path)"))
    # ssd: chunked vs naive scan
    x = jnp.asarray(rng.normal(size=(2, 1024, 8, 32)).astype(np.float32))
    a_log = jnp.asarray(rng.uniform(0, 2, size=(8,)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(2, 1024, 32)).astype(np.float32))
    cc = jnp.asarray(rng.normal(size=(2, 1024, 32)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(1e-3, 0.1, (2, 1024, 8)).astype(np.float32))
    rows.append(("kernels/ssd_scan_1k",
                 _time(jax.jit(ref.ssd_scan), x, a_log, b, cc, dt),
                 "naive recurrence"))
    rows.append(("kernels/ssd_chunked_1k",
                 _time(jax.jit(lambda *a: ssd_chunked(*a, chunk=128)),
                       x, a_log, b, cc, dt), "SSD chunked (model path)"))
    return rows
