"""Out-of-core table: residency-budget sweep + warm-restart TTC.

Two claims, both measured against the fully resident engine on the SAME
graph and config so the rows isolate the spill tier's contribution:

  * ``ooc_budget`` — the engine converges BITWISE-identically (values and
    algorithmic counters) under shrinking device budgets; the rows track
    the paging overhead (spill traffic, prefetch hit rate, slowdown vs
    fully resident) as the budget tightens. The floor row runs at
    ``width + 2`` resident blocks — the minimum the admission guarantee
    allows.
  * ``ooc_restart`` — save_epoch -> restore(verify=True) reconverges from
    the checkpointed fixpoint in a fraction of the cold-start supersteps;
    the derived field carries the warm/cold TTC and iteration ratios that
    README/ROADMAP quote.
"""
from __future__ import annotations

import dataclasses
import shutil
import tempfile
import time

import numpy as np

from repro.core import algorithms as A
from repro.core import graph as G
from repro.core.engine import EngineConfig, StructureAwareEngine
from repro.stream import StreamingEngine, synthetic_stream


def run(n: int = 20000):
    cfg = EngineConfig(t2=1e-8, width=16, block_size=512)
    g = G.powerlaw_graph(n, avg_deg=8, seed=1, weighted=True)
    rows = []

    # -- budget sweep: fully resident baseline, then tightening budgets ----
    full_eng = StructureAwareEngine(g, A.pagerank(), cfg)
    P = full_eng.plan.num_blocks
    t0 = time.perf_counter()
    full = full_eng.run()
    us_full = (time.perf_counter() - t0) * 1e6
    rows.append((
        "ooc/powerlaw/pagerank/resident_all", us_full,
        f"P={P};iters={full.metrics.iterations};"
        f"bytes_loaded={full.metrics.bytes_loaded}"))
    floor = cfg.width + 2
    budgets = sorted({max(3 * P // 4, floor), max(P // 2, floor), floor},
                     reverse=True)
    for budget in budgets:
        if budget >= P:
            continue
        eng = StructureAwareEngine(
            g, A.pagerank(),
            dataclasses.replace(cfg, resident_blocks=budget))
        t0 = time.perf_counter()
        res = eng.run()
        us = (time.perf_counter() - t0) * 1e6
        m = res.metrics
        bitwise = np.array_equal(full.values, res.values)
        rows.append((
            f"ooc/powerlaw/pagerank/resident{budget}", us,
            f"P={P};budget={budget};iters={m.iterations};"
            f"bitwise={bitwise};evictions={m.spill_evictions};"
            f"spilled_mb={m.bytes_spilled / 1e6:.1f};"
            f"fetched_mb={m.bytes_fetched / 1e6:.1f};"
            f"hit_rate={m.prefetch_hit_rate:.2f};"
            f"slowdown_vs_resident={us / max(us_full, 1e-9):.2f}x"))

    # -- warm restart: checkpointed fixpoint vs cold start -----------------
    se = StreamingEngine(g, A.pagerank(), cfg)
    for b in synthetic_stream(g, 2, 200, seed=3, delete_frac=0.2,
                              weighted=True):
        se.ingest(b)
    tmp = tempfile.mkdtemp(prefix="bench_ooc_ck_")
    try:
        t0 = time.perf_counter()
        se.save_epoch(tmp).wait()
        us_save = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        back = StreamingEngine.restore(tmp, A.pagerank(), cfg, verify=True)
        us_warm = (time.perf_counter() - t0) * 1e6
        mutated = se.current_graph()
        t0 = time.perf_counter()
        cold = StructureAwareEngine(mutated, A.pagerank(), cfg).run()
        us_cold = (time.perf_counter() - t0) * 1e6
        wm = back.initial_result.metrics
        agree = np.allclose(back.values, se.values, rtol=1e-4, atol=1e-6)
        rows.append((
            "ooc/powerlaw/pagerank/restart_warm", us_warm,
            f"iters={wm.iterations};cold_iters={cold.metrics.iterations};"
            f"iter_gain={cold.metrics.iterations / max(wm.iterations, 1):.1f}x;"
            f"agree={agree};save_us={us_save:.0f};"
            f"ttc_gain_vs_cold={us_cold / max(us_warm, 1e-9):.2f}x"))
        rows.append((
            "ooc/powerlaw/pagerank/restart_cold", us_cold,
            f"iters={cold.metrics.iterations}"))
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    return rows
