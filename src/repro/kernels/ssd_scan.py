"""Pallas TPU kernel: SSD intra-chunk quadratic term (mamba2 hotspot).

The chunked SSD algorithm (models/ssm.py) splits into a small inter-chunk
recurrence and the dominant *intra-chunk* term

    y[q] = sum_{s<=q} (c_q . b_s) * exp(l_q - l_s) * u[s]        (per head)

which is two MXU matmuls around an elementwise decay mask — exactly one
(Q x N)(N x Q) -> (Q x Q) Gram tile and one (Q x Q)(Q x P) -> (Q x P)
product per (sequence-chunk, head) grid cell, all VMEM-resident.

Grid: (B * nc, H). Block shapes: c/b (Q, N), u (Q, P), ld (Q, 1) — Q=128,
N<=128, P<=128 keeps every operand MXU-aligned and the working set
< 0.5 MiB. Oracle: the y_intra einsum path in models/ssm.py::ssd_chunked
(itself validated against the naive recurrence in ref.ssd_scan).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(c_ref, b_ref, u_ref, l_ref, o_ref, *, q: int):
    c = c_ref[0].astype(jnp.float32)  # (Q, N)
    b = b_ref[0].astype(jnp.float32)  # (Q, N)
    u = u_ref[0].astype(jnp.float32)  # (Q, P)
    ld = l_ref[0].astype(jnp.float32)  # (Q, 1) cumulative log-decay
    gram = jnp.dot(c, b.T, preferred_element_type=jnp.float32)  # (Q, Q)
    ldiff = ld - ld.T  # l_q - l_s
    rows = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    decay = jnp.where(rows >= cols, jnp.exp(ldiff), 0.0)
    o_ref[0] = jnp.dot(gram * decay, u,
                       preferred_element_type=jnp.float32).astype(
        o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_chunk(c, b, u, ld, interpret: bool = True):
    """c, b: (G, Q, N); u: (G, Q, P); ld: (G, Q) cumulative log-decay.
    G = batch * num_chunks * heads (pre-flattened). Returns (G, Q, P)."""
    g, q, n = c.shape
    p = u.shape[-1]
    return pl.pallas_call(
        functools.partial(_kernel, q=q),
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, q, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, q, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, q, p), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, q, 1), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, p), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((g, q, p), u.dtype),
        interpret=interpret,
    )(c, b, u, ld.reshape(g, q, 1))
