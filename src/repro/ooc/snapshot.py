"""Epoch persistence: serialize a StreamingEngine epoch, restart warm.

A :class:`GraphCheckpoint` rides on :class:`repro.ckpt.manager
.CheckpointManager` (atomic tmp+rename publish, async writer, keep-N GC)
and captures one epoch of a :class:`repro.stream.StreamingEngine`:

  * the live base edge set in ORIGINAL vertex ids (the COO truth from
    ``EdgeStore.live_base`` — deliberately a *tuple*, exercising the
    checkpoint treedef round-trip on a real consumer);
  * the converged fixpoint values (original ids);
  * the tile-row mirror, the PSD/calm activity state, the partition
    order, degrees, block-coupling counts and aux — the full epoch
    audit record.

Restore (``StreamingEngine.restore``) rebuilds the epoch geometry
deterministically from the checkpointed COO (``build_plan``'s activity
sort is a pure function of the edge set and config, so this is exactly
the plan-rebuild path every overflow batch already takes) and
warm-starts from the checkpointed values: the verification pass re-heats
every block once (PSD = UNSEEN), but from a fixpoint the deltas die
immediately — the measured warm-vs-cold time-to-convergence ratio in
``benchmarks/bench_ooc.py``. The tiles/psd/calm records make the
checkpoint self-describing and auditable; restore consumes the COO +
values and re-derives the rest, so a checkpoint written under one
residency budget restores under any other.

Snapshots always capture fixpoints: ``StreamingEngine`` reconverges at
the end of every ingest, so ``save_epoch`` between batches is consistent
by construction. Under an out-of-core budget the tile truth comes from
the host mirror (``MutableTiledState``), which spilling never touches —
saving never needs to page spilled blocks back in.
"""
from __future__ import annotations

import numpy as np

from repro.ckpt.manager import CheckpointManager

FORMAT = "graph-epoch-v1"


class GraphCheckpoint:
    """Epoch checkpoint store for a StreamingEngine (see module doc)."""

    def __init__(self, directory: str, keep: int = 3,
                 async_write: bool = True):
        self.manager = CheckpointManager(directory, keep=keep,
                                         async_write=async_write)

    # -- write ---------------------------------------------------------------
    def save(self, streaming, step: int | None = None) -> int:
        """Serialize the engine's current epoch. ``step`` defaults to the
        epoch counter (one checkpoint per ingest generation)."""
        eng = streaming.engine
        plan = eng.plan
        ps, pd, w = streaming.store.live_base()
        step = streaming.epoch if step is None else int(step)
        psd = (eng.last_psd if eng.last_psd is not None
               else np.zeros((plan.num_blocks, eng.config.subblocks),
                             np.float32))
        calm = (eng.last_calm if eng.last_calm is not None
                else np.zeros_like(psd, dtype=np.int32))
        tiles = streaming.tiles
        tree = {
            # original-id COO truth — a TUPLE, so the treedef round-trip
            # is integration-tested by every save/restore cycle
            "edges": (plan.order[ps].astype(np.int64),
                      plan.order[pd].astype(np.int64),
                      np.asarray(w, dtype=np.float32)),
            "values": np.asarray(streaming.values),
            "plan": {"order": plan.order.astype(np.int64)},
            "tiles": {"src": tiles.src, "dst_local": tiles.dstl,
                      "w": tiles.w, "valid": tiles.valid,
                      "fill": tiles.fill, "live": tiles.live},
            "state": {"psd": np.asarray(psd, np.float32),
                      "calm": np.asarray(calm, np.int32)},
            "degrees": {"out": streaming.out_deg, "in": streaming.in_deg},
            "coupling": streaming.W,
            "aux": streaming._aux,
        }
        self.manager.save(step, tree, extra_meta={
            "format": FORMAT, "epoch": int(streaming.epoch),
            "n": int(streaming.n),
            "num_blocks": int(plan.num_blocks),
            "block_size": int(plan.block_size),
            "subblocks": int(eng.config.subblocks),
            "program": type(streaming.program).__name__})
        return step

    def wait(self) -> None:
        self.manager.wait()

    # -- read ----------------------------------------------------------------
    def load(self, step: int | None = None) -> tuple[dict, dict]:
        """(tree, meta) of the requested (default: latest) epoch."""
        tree, meta = self.manager.restore(step)
        if meta.get("format") != FORMAT:
            raise ValueError(
                f"{self.manager.dir} step {meta.get('step')} is not a "
                f"graph epoch checkpoint (format={meta.get('format')!r})")
        return tree, meta
