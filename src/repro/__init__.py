"""repro: structure-aware graph processing + multi-pod LM substrate in JAX."""

__version__ = "0.1.0"
