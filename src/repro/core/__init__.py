"""Structure-aware graph processing (the paper's contribution).

Public API:
    Graph construction  : graph.powerlaw_graph / uniform_graph / from_edges
    Vertex programs     : algorithms.pagerank / sssp / bfs / cc
    Engines             : engine.StructureAwareEngine (paper),
                          baseline.BaselineEngine (Gemini-style),
                          distributed.DistributedEngine (shard_map)
    BC driver           : engine.betweenness
"""
from repro.core import algorithms, degrees, graph, metrics, partition
from repro.core.baseline import BaselineEngine
from repro.core.engine import EngineConfig, RunResult, StructureAwareEngine, betweenness

__all__ = [
    "algorithms", "degrees", "graph", "metrics", "partition",
    "BaselineEngine", "EngineConfig", "RunResult", "StructureAwareEngine",
    "betweenness",
]
