"""Fault-tolerant checkpointing: atomic, async, keep-N, mesh-agnostic.

Layout: <dir>/step_<k>/arrays.npz + meta.json, written to a tmp dir and
renamed (atomic on POSIX) so a crash mid-write never corrupts the latest
checkpoint. Arrays are stored logically-unsharded with their tree structure
in meta; restore lays them out against ANY mesh/sharding (elastic resize —
the reshard test saves on an 8-device mesh and restores on 4).

At real pod scale the same interface writes per-host shards (one npz per
jax.process_index()); the single-host path is what runs here.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np

SEP = "/"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}{SEP}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}{SEP}"))
    else:
        out[prefix[:-1]] = tree
    return out


def _treedef(tree):
    """JSON-able structure spec: the shape of the pytree with leaves
    replaced by their flat storage keys. Recorded in meta.json so restore
    can rebuild the ORIGINAL container types — the key-only _unflatten
    turns list/tuple nodes into string-keyed dicts."""
    def spec(node, prefix=""):
        if isinstance(node, dict):
            return {"t": "dict",
                    "items": {k: spec(v, f"{prefix}{k}{SEP}")
                              for k, v in node.items()}}
        if isinstance(node, (list, tuple)):
            return {"t": "list" if isinstance(node, list) else "tuple",
                    "items": [spec(v, f"{prefix}{i}{SEP}")
                              for i, v in enumerate(node)]}
        return {"t": "leaf", "key": prefix[:-1]}
    return spec(tree)


def _from_treedef(spec, flat: dict):
    t = spec["t"]
    if t == "dict":
        return {k: _from_treedef(v, flat) for k, v in spec["items"].items()}
    if t in ("list", "tuple"):
        items = [_from_treedef(v, flat) for v in spec["items"]]
        return items if t == "list" else tuple(items)
    return flat[spec["key"]]


def _unflatten(flat: dict):
    """Key-only fallback for checkpoints written before the treedef was
    recorded: every interior node comes back as a dict (list/tuple
    structure is unrecoverable from the keys alone)."""
    tree: dict = {}
    for key, v in flat.items():
        parts = key.split(SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


def reshard(tree, shardings):
    """Lay a host-side pytree out against (possibly different) shardings."""
    return jax.tree.map(
        lambda x, s: jax.device_put(np.asarray(x), s), tree, shardings)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)
        # sweep stale tmp dirs left by a crash mid-write: the published
        # step_* dirs are complete by construction (tmp -> rename), so a
        # leftover *.tmp is garbage by definition and must not shadow a
        # future write to the same step
        for name in os.listdir(directory):
            if name.startswith("step_") and name.endswith(".tmp"):
                shutil.rmtree(os.path.join(directory, name),
                              ignore_errors=True)

    # -- write ---------------------------------------------------------------
    def save(self, step: int, tree, extra_meta: dict | None = None):
        # np.asarray preserves leaf dtypes (incl. numpy scalar dtypes —
        # an np.int32 step must not round-trip into an int64 surprise);
        # only plain python scalars fall back to the platform default
        flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
        meta = {"step": step, "time": time.time(),
                "keys": sorted(flat.keys()),
                "treedef": _treedef(tree), **(extra_meta or {})}
        self.wait()  # one in-flight write at a time
        if self.async_write:
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, meta), daemon=True)
            self._thread.start()
        else:
            self._write(step, flat, meta)

    def _write(self, step: int, flat: dict, meta: dict):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.list_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- read ----------------------------------------------------------------
    def list_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, step: int | None = None, shardings=None):
        """Returns (tree, meta). With ``shardings`` (a matching pytree of
        NamedSharding), arrays are device_put against them — this is the
        elastic-resize path."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        # rebuild the original container types from the recorded treedef;
        # pre-treedef checkpoints fall back to the key-only dict shape
        spec = meta.get("treedef")
        tree = (_from_treedef(spec, flat) if spec is not None
                else _unflatten(flat))
        if shardings is not None:
            tree = reshard(tree, shardings)
        return tree, meta
