"""hymba-1.5b [hybrid]: 32L d=1600, PARALLEL attention + mamba heads in
every layer (outputs averaged), 25H GQA kv=5 (head_dim 64), ff=5504,
ssm_state=16. [arXiv:2411.13676; hf]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
    d_ff=5504, vocab_size=32001, head_dim=64,
    ssm_state=16, ssm_heads=25, ssm_head_dim=64,
    parallel_ssm=True,
)
