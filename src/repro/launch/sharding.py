"""GSPMD sharding rules for every (arch x shape) cell.

Policy (DESIGN.md §5):
  * batch shards over ("pod","data") — only gradient all-reduce crosses DCN;
  * "model" carries TP (attention head/ffn-hidden dims, vocab) and EP
    (expert dim) — dims shard only when divisible, else stay replicated
    (the roofline then shows the cost and the hillclimb revisits);
  * ZeRO-1: optimizer moments additionally shard over "data" on the largest
    still-unsharded divisible dim;
  * decode caches shard seq over "model" (flash-decoding combine) and batch
    over ("pod","data"); long_500k (batch=1) shards seq over ALL axes.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.config import ArchConfig, ShapeConfig


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def _div(n: int, mesh: Mesh, axis) -> bool:
    size = 1
    for a in (axis if isinstance(axis, tuple) else (axis,)):
        size *= mesh.shape[a]
    return n % size == 0


def _spec_for_param(path: str, shape: tuple, mesh: Mesh,
                    tied: bool = False, embed_d_shard: bool = False) -> P:
    """Sharding rules keyed on the param path (see module docstring)."""
    m = "model"

    def last_dim_model(ndim):  # shard trailing dim over model
        if _div(shape[-1], mesh, m):
            return P(*([None] * (ndim - 1) + [m]))
        return P()

    if path.endswith("embed"):
        # Vocab-sharding the input table turns every lookup into an
        # all-gather of the whole table; with embed_d_shard (§Perf lever)
        # untied models shard D instead (local gather). Tied models keep
        # vocab-sharding — their head matmul contracts over D and a D-shard
        # would psum (B,S,V).
        if embed_d_shard and not tied and _div(shape[1], mesh, m):
            return P(None, m)
        return P(m, None) if _div(shape[0], mesh, m) else P()
    if path.endswith(("lm_head",)):
        return P(None, m) if _div(shape[1], mesh, m) else P()
    # stacked layer params: leading dim is L
    if "/attn/" in path or "/cross/" in path:
        if path.endswith(("wq", "wk", "wv")):
            return last_dim_model(len(shape))
        if path.endswith("wo"):
            return (P(None, m, None) if _div(shape[1], mesh, m) else P())
    if "/mlp/" in path:
        if path.endswith(("wg", "wu")):
            return last_dim_model(len(shape))
        if path.endswith("wd"):
            return (P(None, m, None) if _div(shape[1], mesh, m) else P())
    if "/moe/" in path:
        if path.endswith("router"):
            return last_dim_model(len(shape))
        if path.endswith(("w_gate", "w_up", "w_down")):  # (L, E, a, b): EP
            if _div(shape[1], mesh, m):
                return P(None, m, None, None)
            # fall back to TP on the hidden dim
            hid = 3 if path.endswith(("w_gate", "w_up")) else 2
            if _div(shape[hid], mesh, m):
                spec = [None] * len(shape)
                spec[hid] = m
                return P(*spec)
            return P()
        if path.endswith(("shared_gate", "shared_up")):
            return last_dim_model(len(shape))
        if path.endswith("shared_down"):
            return (P(None, m, None) if _div(shape[1], mesh, m) else P())
    if "/ssm/" in path:
        if path.endswith(("in_x", "in_z", "in_dt")):
            return last_dim_model(len(shape))
        if path.endswith("out"):
            return (P(None, m, None) if _div(shape[1], mesh, m) else P())
        if path.endswith(("a_log", "dt_bias", "d_skip", "ssm_norm")):
            return last_dim_model(len(shape))
    return P()  # norms, conv, biases, small projections: replicated


def _is_tied(params_shape: Any) -> bool:
    return isinstance(params_shape, dict) and "lm_head" not in params_shape


def param_specs(params_shape: Any, mesh: Mesh,
                embed_d_shard: bool = False):
    """Pytree of NamedSharding matching a (possibly abstract) param tree."""
    tied = _is_tied(params_shape)

    def one(path, leaf):
        return NamedSharding(mesh, _spec_for_param(
            _path_str(path), leaf.shape, mesh, tied, embed_d_shard))
    return jax.tree_util.tree_map_with_path(one, params_shape)


def zero1_specs(params_shape: Any, mesh: Mesh,
                embed_d_shard: bool = False):
    """Optimizer-moment shardings: param spec + extra 'data' shard on the
    largest still-unsharded divisible dim (ZeRO-1)."""
    tied = _is_tied(params_shape)

    def one(path, leaf):
        base = _spec_for_param(_path_str(path), leaf.shape, mesh, tied,
                               embed_d_shard)
        spec = list(base) + [None] * (len(leaf.shape) - len(base))
        # densest remaining dim first
        order = sorted(range(len(leaf.shape)),
                       key=lambda i: -leaf.shape[i])
        for i in order:
            if spec[i] is None and _div(leaf.shape[i], mesh, "data") \
                    and leaf.shape[i] >= mesh.shape["data"] * 8:
                spec[i] = "data"
                break
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(one, params_shape)


def state_specs(state_shape: Any, mesh: Mesh, zero1: bool = True,
                embed_d_shard: bool = False):
    """Shardings for the full TrainState {params, opt{m,v,step}}."""
    p = param_specs(state_shape["params"], mesh, embed_d_shard)
    mom = (zero1_specs(state_shape["params"], mesh, embed_d_shard) if zero1
           else p)
    return {"params": p,
            "opt": {"m": mom, "v": mom,
                    "step": NamedSharding(mesh, P())}}


def batch_specs(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                batch_size: int | None = None):
    """Shardings for the input batch dict."""
    from repro.launch.mesh import batch_axes
    b = batch_size or shape.global_batch
    ba = batch_axes(mesh)
    bspec = ba if _div(b, mesh, ba) else ()
    out = {}

    def named(*spec):
        return NamedSharding(mesh, P(*spec))

    out["tokens"] = named(bspec or None, None)
    if shape.kind == "train":
        out["targets"] = named(bspec or None, None)
    if cfg.num_patches:
        out["patches"] = named(bspec or None, None, None)
    if cfg.is_encdec:
        out["frames"] = named(bspec or None, None, None)
    return out


def cache_sharding(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                   cache_shape: Any):
    """Shardings for the decode cache pytree (see module docstring)."""
    from repro.launch.mesh import batch_axes
    ba = batch_axes(mesh)
    b = shape.global_batch
    long_ctx = b == 1
    all_axes = tuple(mesh.axis_names)

    def one(path, leaf):
        name = _path_str(path)
        shp = leaf.shape
        if name == "pos":
            return NamedSharding(mesh, P())
        if name in ("k", "v", "cross_k", "cross_v"):
            # (L, B, S, Hkv, Dh)
            if long_ctx:
                seq_ax = all_axes if _div(shp[2], mesh, all_axes) else "model"
                return NamedSharding(mesh, P(None, None, seq_ax, None, None))
            bspec = ba if _div(shp[1], mesh, ba) else None
            seq_ax = "model" if _div(shp[2], mesh, "model") else None
            return NamedSharding(mesh, P(None, bspec, seq_ax, None, None))
        if name == "ssm_state":  # (L, B, H, P, N)
            h_ax = "model" if _div(shp[2], mesh, "model") else None
            bspec = ba if _div(shp[1], mesh, ba) else None
            return NamedSharding(mesh, P(None, bspec, h_ax, None, None))
        if name == "conv":  # (L, B, K-1, CH)
            bspec = ba if _div(shp[1], mesh, ba) else None
            return NamedSharding(mesh, P(None, bspec, None, None))
        return NamedSharding(mesh, P())
    return jax.tree_util.tree_map_with_path(one, cache_shape)


def logits_spec(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                ndim: int = 2):
    from repro.launch.mesh import batch_axes
    ba = batch_axes(mesh)
    b = shape.global_batch
    bspec = ba if _div(b, mesh, ba) else None
    v_ax = "model" if _div(cfg.vocab_padded, mesh, "model") else None
    if ndim == 2:
        return NamedSharding(mesh, P(bspec, v_ax))
    return NamedSharding(mesh, P(bspec, None, v_ax))
