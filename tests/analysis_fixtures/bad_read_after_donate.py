"""RA002 fixture: a buffer is read after being passed through a
donate_argnums position (its device memory has been reused)."""
import jax
import jax.numpy as jnp


def _step(buf, delta):
    return buf + delta


def commit(buf, delta):
    fn = jax.jit(_step, donate_argnums=(0,))
    out = fn(buf, delta)
    checksum = buf.sum()  # read of the donated (freed) buffer
    return out, checksum


def commit_ok(buf, delta):
    """Rebinding the name before the next read is the correct idiom."""
    fn = jax.jit(_step, donate_argnums=(0,))
    buf = fn(buf, delta)
    return buf, buf.sum()
